"""Serve a (reduced) assigned-architecture LM with the continuous-batching
engine: fused one-call prefill, slot-based KV cache, mid-flight admission,
greedy or temperature/top-k sampling — the decode path the sparse-sparse
topk dispatch targets.

Run: PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine
from repro.runtime.scheduler import Request, SamplingParams

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    engine = Engine(cfg, mesh, max_seq=64, n_slots=args.slots)
    rng = np.random.default_rng(0)
    # mixed prompt lengths + budgets: the case continuous batching wins
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        8 + 4 * (i % 3)).tolist(),
                    max_new_tokens=max(1, args.gen - 4 * (i % 3)),
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k, seed=i))
            for i in range(args.requests)]
    out, stats = engine.serve(reqs)
    print(f"arch={cfg.name} served {len(out)} requests in "
          f"{stats['wall_s']:.2f}s: {stats['tok_s']:.1f} tok/s, "
          f"{stats['decode_steps']} decode steps, "
          f"{stats['prefill_calls']} prefill calls (1 per prompt)")
    for uid in sorted(out)[:2]:
        print(f"  req {uid} ({len(out[uid])} toks, "
              f"ttft {stats['ttft_s'][uid]*1e3:.0f}ms):", out[uid][:12])
