"""Serve a (reduced) assigned-architecture LM with the continuous-batching
engine: fused one-call prefill, slot-based KV cache, mid-flight admission,
greedy or temperature/top-k sampling — the decode path the sparse-sparse
topk dispatch targets.

Runs with telemetry on and ends with a human-readable summary: throughput,
TTFT p50/p95, stage breakdown, and the realized k/N per sparse layer (what
fraction of each FFN actually fired, vs the configured k).

Run: PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine
from repro.obs import Telemetry
from repro.runtime.scheduler import Request, SamplingParams


def _ms(v):
    return "n/a" if v is None else f"{v * 1e3:.0f}ms"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--no-telemetry", action="store_true",
                    help="serve without tracing/metrics (skips the summary)")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="PATH",
                    help="also stream span/request events to a JSONL file")
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    tel = (Telemetry.off() if args.no_telemetry
           else Telemetry.on(jsonl_path=args.telemetry_jsonl,
                             sparsity_every=4))
    engine = Engine(cfg, mesh, max_seq=64, n_slots=args.slots, telemetry=tel)
    rng = np.random.default_rng(0)
    # mixed prompt lengths + budgets: the case continuous batching wins
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        8 + 4 * (i % 3)).tolist(),
                    max_new_tokens=max(1, args.gen - 4 * (i % 3)),
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k, seed=i))
            for i in range(args.requests)]
    out, stats = engine.serve(reqs)
    print(f"arch={cfg.name} served {len(out)} requests in "
          f"{stats['wall_s']:.2f}s: {stats['tok_s']:.1f} tok/s, "
          f"{stats['decode_steps']} decode steps, "
          f"{stats['prefill_calls']} prefill calls (1 per prompt)")
    for uid in sorted(out)[:2]:
        print(f"  req {uid} ({len(out[uid])} toks, "
              f"ttft {stats['ttft_s'][uid]*1e3:.0f}ms):", out[uid][:12])
    if tel.enabled:
        snap = engine.metrics_snapshot()
        hists = snap["metrics"]["histograms"]
        ttft = hists.get("serve.ttft_s", {})
        itl = hists.get("serve.itl_s", {})
        print("-- telemetry ----------------------------------------------")
        print(f"  ttft  p50 {_ms(ttft.get('p50'))}  "
              f"p95 {_ms(ttft.get('p95'))}")
        print(f"  itl   p50 {_ms(itl.get('p50'))}  "
              f"p95 {_ms(itl.get('p95'))}")
        stages = sorted(snap["stages"].items(),
                        key=lambda kv: -kv[1]["total_s"])
        brk = "  ".join(f"{name} {t['total_s']:.2f}s" for name, t in stages)
        print(f"  stages: {brk}")
        layers = snap["sparsity"]["layers"]
        if layers:
            print("  realized sparsity (mean k/N fired per layer):")
            for name in sorted(layers):
                e = layers[name]
                rk = e.get("realized_k_frac")
                cfg_k = e.get("configured_k_frac")
                ov = e.get("winner_overlap")
                line = f"    {name}: k/N {rk:.4f}" if rk is not None \
                    else f"    {name}: k/N n/a"
                if cfg_k:
                    line += f" (configured {cfg_k:.4f})"
                if ov is not None:
                    line += f", step-to-step winner overlap {ov:.2f}"
                print(line)
        else:
            print("  realized sparsity: no sparse layers in this config")
        tel.close()
