"""Serve a (reduced) assigned-architecture LM with batched requests and a
KV cache — the decode path that the sparse-sparse topk dispatch targets.

Run: PYTHONPATH=src python examples/serve_lm.py --arch yi-6b
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Server

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, max_seq=64)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, 8)).astype(np.int32)
    out = server.generate(prompts, args.gen)
    print(f"arch={cfg.name} generated {out.shape}:")
    for row in out[:2]:
        print(" ", row.tolist())
