"""The paper's technique inside a transformer (their §6.4 direction):
train a reduced LM with CS-packed FFNs + k-WTA, against the dense
baseline, and compare compiled FLOPs per step + losses.

Run: PYTHONPATH=src python examples/sparse_sparse_lm.py [--steps 60]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.core.api import DENSE, SparsityConfig
from repro.data import batch_for
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.optim import init_state


class _Shape:
    seq_len = 64
    global_batch = 8


def run(tag, sparsity, steps):
    cfg = get_config("smollm-360m").reduced(
        d_model=128, d_ff=512, vocab_size=512, n_heads=4, n_kv_heads=2,
        head_pad=0, ffn_sparsity=sparsity)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    train_step, acfg = make_train_step(cfg, TrainConfig(lr=1e-3))
    opt = init_state(params, acfg)
    jitted = jax.jit(train_step)
    b0 = {k: jnp.asarray(v) for k, v in batch_for(cfg, _Shape, 0).items()}
    from repro.launch.hlo import compiled_flops
    flops = compiled_flops(jitted.lower(params, opt, b0).compile())
    for s in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_for(cfg, _Shape, s).items()}
        params, opt, m = jitted(params, opt, batch)
    print(f"[{tag:13s}] final loss {float(m['loss']):.4f} "
          f"step GFLOPs {flops/1e9:.3f}")
    return flops


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    fd = run("dense", DENSE, args.steps)
    fs = run("sparse-sparse",
             SparsityConfig(n=4, k_frac=0.125, kwta_impl="bisect"),
             args.steps)
    print(f"FFN sparse-sparse cuts compiled step FLOPs by "
          f"{fd / fs:.2f}x at n=4 (75% weight + 87.5% activation sparsity)")
