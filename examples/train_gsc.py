"""End-to-end driver: train the paper's GSC CNN (Table 1) for a few
hundred steps on synthetic keyword-spectrogram data, in all three
variants, and report loss/accuracy + per-variant compiled FLOPs —
the reproduction of the paper's §4 experiment shape.

Run: PYTHONPATH=src python examples/train_gsc.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import gsc_batch
from repro.models import gsc_cnn as G
from repro.optim import AdamWConfig, apply_updates, init_state


def train(variant: str, steps: int, batch: int = 64):
    cfg = G.GSCConfig(variant=variant)
    params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
    acfg = AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt = init_state(params, acfg)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: G.loss_fn(p, batch, cfg), has_aux=True,
            allow_int=True)(params)
        params, opt, _ = apply_updates(params, grads, opt, acfg)
        return params, opt, m

    t0 = time.time()
    acc = loss = 0.0
    for s in range(steps):
        b = gsc_batch(seed=0, step=s, batch=batch)
        batch_j = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
        params, opt, m = step_fn(params, opt, batch_j)
        if s % 50 == 0 or s == steps - 1:
            loss, acc = float(m["loss"]), float(m["accuracy"])
            print(f"  [{variant}] step {s:4d} loss {loss:.3f} acc {acc:.3f}")
    dt = time.time() - t0
    # held-out accuracy on fresh steps
    accs = []
    for s in range(steps, steps + 5):
        b = gsc_batch(seed=0, step=s, batch=batch)
        _, m = G.loss_fn(params, {"x": jnp.asarray(b["x"]),
                                  "y": jnp.asarray(b["y"])}, cfg)
        accs.append(float(m["accuracy"]))
    print(f"  [{variant}] heldout acc {np.mean(accs):.3f} ({dt:.1f}s)")
    return np.mean(accs)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    for v in ["dense", "sparse_dense", "sparse_sparse"]:
        train(v, args.steps)
