"""Quickstart: the paper's Complementary Sparsity in 60 lines.

Builds a packed CS linear layer, shows the three execution paths agree
with the masked dense matmul, demonstrates the multiplicative
sparse-sparse FLOP savings on the compiled artifact, and trains a tiny
sparse-sparse MLP.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CSLayout, SparsityConfig, cs_matmul, cs_topk_matmul,
                        kwta, make_routes, pack_dense, routes_to_mask,
                        packed_bytes, flops_dense, flops_cs_matmul,
                        flops_cs_topk)

# --- 1. Combine (offline): overlay N=8 complementary sparse columns ------
D_IN, D_OUT, N, K = 512, 512, 8, 64
lay = CSLayout(D_IN, D_OUT, N)
route = make_routes(lay, seed=0)
rng = np.random.default_rng(0)
w_sparse = rng.normal(size=(D_IN, D_OUT)).astype(np.float32) \
    * routes_to_mask(lay, route)        # 87.5% weight-sparse network
packed = jnp.asarray(pack_dense(lay, w_sparse, route))
route = jnp.asarray(route)
print(f"packing: {packed_bytes(lay)}")

# --- 2. Multiply-Route-Sum (sparse-dense) --------------------------------
x = jnp.asarray(rng.normal(size=(4, D_IN)).astype(np.float32))
y_faithful = cs_matmul(x, packed, route)
y_ref = x @ jnp.asarray(w_sparse)
print("sparse-dense max err:", float(jnp.abs(y_faithful - y_ref).max()))

# --- 3. Select (k-WTA) + sparse-sparse ------------------------------------
xs = kwta(x, K)                          # 87.5% activation-sparse
y_ss = cs_topk_matmul(xs, packed, route, K)
print("sparse-sparse max err:", float(jnp.abs(y_ss - xs @ jnp.asarray(w_sparse)).max()))
fd = flops_dense(4, D_IN, D_OUT)
fsd = flops_cs_matmul(4, D_IN, D_OUT, N)
fss = flops_cs_topk(4, K, D_OUT)
print(f"FLOPs  dense={fd:,}  sparse-dense={fsd:,} ({fd//fsd}x)  "
      f"sparse-sparse={fss:,} ({fd//fss}x compute; memory also /{N} "
      f"-> {fd//fss*N}x multiplicative, paper Fig. 1)")

# --- 4. Train a sparse-sparse MLP end to end ------------------------------
from repro.core.layers import packed_linear_init, packed_linear_apply, apply_kwta
cfg = SparsityConfig(n=4, k_frac=0.125)
key = jax.random.PRNGKey(0)
p1, _ = packed_linear_init(key, 64, 256, cfg, seed=1)
p2, _ = packed_linear_init(key, 256, 10, SparsityConfig(n=2), seed=2)
params = {"l1": p1, "l2": p2}

xb = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
yb = (xb[:, 0] > 0).astype(jnp.int32) + 2 * (xb[:, 1] > 0).astype(jnp.int32)

def loss_fn(params):
    h = packed_linear_apply(params["l1"], xb, cfg)
    h = apply_kwta(jax.nn.relu(h), cfg)          # Select: 12.5% winners
    logits = packed_linear_apply(params["l2"], h, SparsityConfig(n=2))[:, :4]
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(256), yb])

step = jax.jit(lambda p: jax.tree.map(
    lambda a, g: a - 0.5 * g if a.dtype.kind == "f" else a,
    p, jax.grad(loss_fn, allow_int=True)(p)))
for i in range(101):
    params = step(params)
    if i % 25 == 0:
        print(f"step {i:3d} sparse-sparse MLP loss {float(loss_fn(params)):.4f}")
