"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  bench_gsc           — Tables 2/3/4 (end-to-end GSC throughput + energy)
  bench_sparse_matmul — Figure 6 (structured-sparsity matmul paths)
  bench_resources     — Figures 15-18 (conv-block resource scaling)
  bench_kwta          — Figures 19-20 (k-WTA cost scaling)
  bench_serve         — serving: continuous batching vs static, TTFT

Usage: PYTHONPATH=src python -m benchmarks.run [--only gsc,...]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def _report(name: str, us_per_call: float, derived=None) -> None:
    d = json.dumps(derived or {}, sort_keys=True).replace(",", ";")
    print(f"{name},{us_per_call:.2f},{d}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: gsc,sparse_matmul,"
                         "resources,kwta,serve")
    args = ap.parse_args()
    from benchmarks import bench_gsc, bench_kwta, bench_resources, \
        bench_serve, bench_sparse_matmul
    mods = {"gsc": bench_gsc, "sparse_matmul": bench_sparse_matmul,
            "resources": bench_resources, "kwta": bench_kwta,
            "serve": bench_serve}
    sel = (args.only.split(",") if args.only else list(mods))
    print("name,us_per_call,derived")
    failed = []
    for name in sel:
        try:
            mods[name].run(_report)
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
