"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  bench_gsc           — Tables 2/3/4 (end-to-end GSC throughput + energy)
  bench_sparse_matmul — Figure 6 (structured-sparsity matmul paths)
  bench_resources     — Figures 15-18 (conv-block resource scaling)
  bench_kwta          — Figures 19-20 (k-WTA cost scaling)
  bench_serve         — serving: continuous batching vs static, TTFT

Usage: PYTHONPATH=src python -m benchmarks.run [--only gsc,...]
                                               [--json BENCH_serve.json]

``--json OUT`` additionally writes every collected row to a JSON file
(``{"schema_version", "rows": [{"name", "us_per_call", ...derived}],
"benches": [...]}``) — the machine-readable artifact future PRs gate perf
on (CI uploads ``BENCH_serve.json`` from ``--only serve``).  Schema v2
adds TTFT/ITL percentile and realized-sparsity columns to the serve
telemetry row (see repro.obs.export).
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def _report(name: str, us_per_call: float, derived=None) -> None:
    d = json.dumps(derived or {}, sort_keys=True).replace(",", ";")
    print(f"{name},{us_per_call:.2f},{d}", flush=True)


class _Collector:
    """Wraps the CSV reporter; also accumulates rows for ``--json``."""

    def __init__(self):
        self.rows = []

    def __call__(self, name: str, us_per_call: float, derived=None) -> None:
        _report(name, us_per_call, derived)
        row = {"name": name, "us_per_call": round(float(us_per_call), 2)}
        row.update(derived or {})
        self.rows.append(row)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: gsc,sparse_matmul,"
                         "resources,kwta,serve")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write collected rows to OUT as JSON "
                         "(e.g. BENCH_serve.json for the CI artifact)")
    args = ap.parse_args()
    from benchmarks import bench_gsc, bench_kwta, bench_resources, \
        bench_serve, bench_sparse_matmul
    mods = {"gsc": bench_gsc, "sparse_matmul": bench_sparse_matmul,
            "resources": bench_resources, "kwta": bench_kwta,
            "serve": bench_serve}
    sel = (args.only.split(",") if args.only else list(mods))
    report = _Collector()
    print("name,us_per_call,derived")
    failed = []
    for name in sel:
        try:
            mods[name].run(report)
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            traceback.print_exc()
    if args.json:
        from repro.obs.export import SCHEMA_VERSION
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benches": [n for n in sel if n not in failed],
                       "failed": failed, "rows": report.rows}, f, indent=2)
        print(f"wrote {len(report.rows)} rows to {args.json}",
              file=sys.stderr)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
