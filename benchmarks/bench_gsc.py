"""Paper Tables 2/3/4 analog: end-to-end GSC network throughput.

The paper measures words/sec on two FPGAs for dense / sparse-dense /
sparse-sparse implementations.  The container is a CPU, so we report
three graded quantities per variant:

  * **HLO FLOPs per inference** from the compiled artifact — the
    hardware-independent validation of the paper's multiplicative-MACs
    claim (their Fig. 1),
  * **theoretical MAC counts** (their accounting),
  * **CPU wall-clock throughput** (words/sec) as a sanity signal.

'Full chip' (Table 3) maps to batched multi-stream throughput (batch=64);
'energy' (Table 4) maps to FLOPs/word (proportional to energy on
fixed-voltage silicon).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gsc_cnn as G
from repro.launch.hlo import compiled_flops


def _compiled_flops(cfg, batch):
    x = jax.ShapeDtypeStruct((batch, 32, 32, 1), jnp.float32)
    params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
    fn = jax.jit(lambda p, x: G.forward(p, x, cfg))
    compiled = fn.lower(params, x).compile()
    return compiled_flops(compiled), fn, params


def _throughput(fn, params, batch, iters=20):
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 32, 32, 1))
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(params, x).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return batch / dt, dt


def run(report):
    variants = ["dense", "sparse_dense", "sparse_sparse"]
    base_flops = base_tp = None
    macs = G.theoretical_macs(G.GSCConfig())
    for batch, tag in [(1, "single"), (64, "fullchip")]:
        for v in variants:
            cfg = G.GSCConfig(variant=v)
            flops, fn, params = _compiled_flops(cfg, batch)
            tp, dt = _throughput(fn, params, batch)
            if v == "dense":
                base_flops, base_tp = flops, tp
            report(f"gsc_{tag}_{v}", dt * 1e6 / batch, {
                "words_per_s": round(tp, 1),
                "hlo_flops_per_word": round(flops / batch),
                "flops_reduction_vs_dense": round(base_flops / flops, 2),
                "speedup_vs_dense": round(tp / base_tp, 2),
            })
    report("gsc_theoretical_macs", 0.0, {
        "dense": macs["dense"],
        "sd_reduction": round(macs["speedup_sd"], 1),
        "ss_reduction": round(macs["speedup_ss"], 1),
        "paper_measured_sd": 11.7, "paper_measured_ss": 33.6,
    })
