"""Paper Figure 6 analog: structured-sparsity matmul paths vs dense.

The paper benchmarks OneAPI CSR/BSR sparse kernels on a CPU and shows
unstructured sparsity barely helps while structure does.  Our analog
compares, on a 1024x1024 matmul at several pack factors:

  dense matmul | CS faithful path | CS decompress path | CS topk path

reporting compiled HLO FLOPs (the structural claim) and CPU wall-time
(the 'current hardware' sanity signal, same spirit as the paper's Fig. 6).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CSLayout, cs_matmul, cs_matmul_dense, cs_topk_matmul,
                        kwta, make_routes, pack_dense, routes_to_mask)
from repro.launch.hlo import compiled_flops


def _time(fn, *args, iters=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters


def _flops(fn, *args):
    return compiled_flops(jax.jit(fn).lower(*args).compile())


def run(report):
    d, b = 1024, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (b, d))
    w_dense = jax.random.normal(jax.random.PRNGKey(1), (d, d)) / 32.0
    dense_fn = jax.jit(lambda x: x @ w_dense)
    t_dense = _time(dense_fn, x)
    f_dense = _flops(lambda x: x @ w_dense, x)
    report("fig6_dense_1024", t_dense * 1e6, {"hlo_flops": f_dense})

    for n in [4, 8, 16, 32]:
        lay = CSLayout(d, d, n)
        # shared routes (the MXU-shaped variant measured in §Perf)
        g = lay.groups
        route = jnp.asarray(make_routes(CSLayout(d, n, n), 0))
        packed = jax.random.normal(jax.random.PRNGKey(n), (g, d // n, n)) / 32.0

        had = jax.jit(lambda x: cs_matmul(x, packed, route))
        dec = jax.jit(lambda x: cs_matmul_dense(x, packed, route))
        k = d // n
        xs = kwta(x, k)
        top = jax.jit(lambda xs: cs_topk_matmul(xs, packed, route, k))

        t_h, f_h = _time(had, x), _flops(lambda x: cs_matmul(x, packed, route), x)
        t_d, f_d = _time(dec, x), _flops(lambda x: cs_matmul_dense(x, packed, route), x)
        t_t, f_t = _time(top, xs), _flops(lambda xs: cs_topk_matmul(xs, packed, route, k), xs)
        report(f"fig6_cs_faithful_n{n}", t_h * 1e6, {
            "hlo_flops": f_h, "flops_cut": round(f_dense / f_h, 2),
            "speedup": round(t_dense / t_h, 2)})
        report(f"fig6_cs_decompress_n{n}", t_d * 1e6, {
            "hlo_flops": f_d, "speedup": round(t_dense / t_d, 2)})
        report(f"fig6_cs_sparse_sparse_n{n}", t_t * 1e6, {
            "hlo_flops": f_t, "flops_cut": round(f_dense / f_t, 2),
            "speedup": round(t_dense / t_t, 2)})
