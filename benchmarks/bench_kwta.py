"""Paper Figures 19-20 analog: k-WTA cost scaling with sparsity.

The paper shows k-WTA resource use falls almost linearly as K decreases
and is small next to the convolutions.  We report HLO FLOPs + wall time
of the three k-WTA implementations (exact top-k, histogram, bisection)
over the paper's 1500-wide activation at several K, plus the
kwta-vs-conv cost ratio (their Fig. 20).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import kwta, kwta_bisect, kwta_hist
from repro.launch.hlo import compiled_flops, cost_analysis_dict


def _cost(fn, x):
    c = cost_analysis_dict(jax.jit(fn).lower(x).compile())
    f = jax.jit(fn)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(x).block_until_ready()
    return c["flops"], (time.perf_counter() - t0) / 20


def run(report):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 1500))
    for k in [375, 225, 150, 75]:  # 75%..95% sparse
        for name, fn in [("topk", lambda x, k=k: kwta(x, k)),
                         ("hist", lambda x, k=k: kwta_hist(x, k)),
                         ("bisect", lambda x, k=k: kwta_bisect(x, k))]:
            flops, dt = _cost(fn, x)
            report(f"fig19_kwta_{name}_k{k}", dt * 1e6,
                   {"hlo_flops": int(flops)})
    # Fig 20: k-WTA vs the conv it feeds (1x1 [64:64] dense equivalent)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    xc = jax.random.normal(jax.random.PRNGKey(2), (64, 100, 64))
    conv_flops = compiled_flops(jax.jit(lambda x: x @ w).lower(xc).compile())
    kw_flops = compiled_flops(jax.jit(lambda x: kwta(x, 8)).lower(xc).compile())
    report("fig20_kwta_vs_conv", 0.0, {
        "kwta_fraction_of_conv": round(kw_flops / conv_flops, 3)})
