"""Serving throughput: continuous batching vs the static-batch path.

Measures tok/s and time-to-first-token across decode batch sizes (slot
counts) and the three sparsity configs of the paper's story (dense,
weight-sparse, sparse-sparse FFNs via the kwta/packed-matmul paths).  The
acceptance bar: continuous batching >= static batch at batch 4, with the
fused prefill issuing ONE compiled call per prompt.

Usage: PYTHONPATH=src python -m benchmarks.run --only serve
   or: PYTHONPATH=src python benchmarks/bench_serve.py
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.configs import get_config
from repro.core.api import DENSE, SparsityConfig
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine
from repro.obs import Telemetry
from repro.obs.export import latency_columns, sparsity_columns
from repro.runtime.scheduler import Request

PROMPT_LEN = 16
GEN = 24

VARIANTS = [
    ("dense", DENSE),
    ("weight_sparse", SparsityConfig(n=4)),
    ("sparse_sparse", SparsityConfig(n=4, k_frac=0.125)),
]


def _mk_engine(sparsity, n_slots, use_pallas=None, telemetry=None,
               max_seq=None, **engine_kw):
    cfg = get_config("smollm-360m").reduced(
        d_model=128, d_ff=512, vocab_size=512, n_heads=4, n_kv_heads=2,
        head_pad=0, ffn_sparsity=sparsity)
    mesh = make_mesh((1, 1), ("data", "model"))
    return Engine(cfg, mesh, max_seq=max_seq or PROMPT_LEN + GEN + 1,
                  n_slots=n_slots, use_pallas=use_pallas,
                  telemetry=telemetry, **engine_kw)


def _requests(engine, n, gen=GEN):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, engine.cfg.vocab_size,
                                        PROMPT_LEN).tolist(),
                    max_new_tokens=gen)
            for i in range(n)]


def _mixed_requests(vocab, lens, gens, seed=0):
    """Fresh request objects (the engine mutates none, but fresh lists
    keep runs independent) with per-request prompt lengths."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, vocab, n).tolist(),
                    max_new_tokens=g)
            for i, (n, g) in enumerate(zip(lens, gens))]


def _bench_static(engine, batch):
    prompts = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (batch, PROMPT_LEN)).astype(np.int32)
    engine.generate_static(prompts, 2)  # warm the decode jit
    t0 = time.perf_counter()
    out = engine.generate_static(prompts, GEN)
    dt = time.perf_counter() - t0
    # static TTFT = the whole stepwise prefill of the batch
    t0 = time.perf_counter()
    engine.generate_static(prompts, 1)
    ttft = time.perf_counter() - t0
    return out.size / dt, ttft


def _bench_continuous(engine, n_requests):
    engine.serve(_requests(engine, 1, gen=2))  # warm prefill+decode jits
    out, stats = engine.serve(_requests(engine, n_requests))
    total = sum(len(v) for v in out.values())
    ttft = float(np.mean([v for v in stats["ttft_s"].values()]))
    return total / stats["wall_s"], ttft, stats


def run(report):
    # -- continuous vs static at batch 4, per sparsity variant --------------
    for name, sp in VARIANTS:
        engine = _mk_engine(sp, n_slots=4)
        st_tps, st_ttft = _bench_static(engine, batch=4)
        ct_tps, ct_ttft, stats = _bench_continuous(engine, n_requests=8)
        report(f"serve_{name}_batch4", 0.0, {
            "static_tok_s": round(st_tps, 1),
            "continuous_tok_s": round(ct_tps, 1),
            "speedup": round(ct_tps / st_tps, 2),
            "static_ttft_ms": round(st_ttft * 1e3, 1),
            "continuous_ttft_ms": round(ct_ttft * 1e3, 1),
            # 9 = 1 warmup + 8 timed prompts; must stay 1.0
            "prefill_calls_per_prompt": round(stats["prefill_calls"] / 9, 2),
            "decode_steps": stats["decode_steps"],
        })
    # -- sparse-sparse decode through the batched Pallas kernel -------------
    # 'force' engages the topk_gather kernel everywhere (interpret fallback
    # on CPU): ONE launch per sparse layer covering the whole decode batch,
    # consuming the k-WTA support handed off by the Select.
    engine = _mk_engine(VARIANTS[2][1], n_slots=4, use_pallas="force")
    ct_tps, ct_ttft, stats = _bench_continuous(engine, n_requests=8)
    report("serve_sparse_sparse_pallas_batch4", 0.0, {
        "continuous_tok_s": round(ct_tps, 1),
        "continuous_ttft_ms": round(ct_ttft * 1e3, 1),
        "decode_steps": stats["decode_steps"],
    })
    # -- batch scaling for the sparse-sparse engine -------------------------
    for slots in (1, 2, 8):
        engine = _mk_engine(VARIANTS[2][1], n_slots=slots)
        tps, ttft, _ = _bench_continuous(engine, n_requests=2 * slots)
        report(f"serve_sparse_sparse_slots{slots}", 0.0, {
            "continuous_tok_s": round(tps, 1),
            "continuous_ttft_ms": round(ttft * 1e3, 1),
        })
    # -- paged KV cache: token parity + throughput (ISSUE 9) ----------------
    # Mixed prompt lengths across 8 requests / 4 slots; the contiguous
    # engine is the oracle — the paged engine (page-table decode, chunked
    # prefill) must generate the exact same greedy tokens.
    sp = VARIANTS[2][1]
    plens = [5, 19, 3, 26, 9, 14, 7, 22]
    pgens = [6 + (i % 5) for i in range(8)]

    def _parity_reqs(vocab):
        return _mixed_requests(vocab, plens, pgens)

    eng_c = _mk_engine(sp, n_slots=4)
    # warm with the FULL workload: fused prefill compiles per prompt
    # bucket, and the mixed lengths span several buckets
    eng_c.serve(_parity_reqs(eng_c.cfg.vocab_size))
    t0 = time.perf_counter()
    out_c, _ = eng_c.serve(_parity_reqs(eng_c.cfg.vocab_size))
    dt_c = time.perf_counter() - t0
    eng_p = _mk_engine(sp, n_slots=4, kv_layout="paged", page_size=8,
                       prefill_chunk=8, params=eng_c.params)
    eng_p.serve(_parity_reqs(eng_p.cfg.vocab_size)[:1])  # warm jits
    t0 = time.perf_counter()
    out_p, stats_p = eng_p.serve(_parity_reqs(eng_p.cfg.vocab_size))
    dt_p = time.perf_counter() - t0
    assert out_p == out_c, "paged decode must be token-identical to the " \
        "contiguous oracle on the mixed-length parity workload"
    n_tok = sum(len(v) for v in out_p.values())
    report("serve_paged_parity_batch4", 0.0, {
        "parity": True,
        "contiguous_tok_s": round(n_tok / dt_c, 1),
        "paged_tok_s": round(n_tok / dt_p, 1),
        "prefill_chunks": stats_p["prefill_chunks"],
        "pages_capacity": stats_p["pages_capacity"],
        "page_size": stats_p["page_size"],
    })
    # -- grow-on-demand vs reserve-on-admit at EQUAL pool size (ISSUE 10) ---
    # Large decode budgets make the reserve policy's worst-case pinning
    # expensive: the same 12-page pool admits strictly more concurrent
    # requests when chains grow lazily (preemption handles the rare
    # genuine exhaustion), which is exactly the batch-size headroom the
    # sparse-sparse decode kernels feed on.  Token parity is asserted
    # against the contiguous oracle for BOTH policies.
    glens = [5, 19, 3, 26, 9, 14, 7, 22]
    ggens = [20, 16, 12, 18, 20, 16, 12, 14]
    eng_o = _mk_engine(sp, n_slots=4, max_seq=48)
    eng_o.serve(_mixed_requests(eng_o.cfg.vocab_size, glens, ggens))
    t0 = time.perf_counter()
    out_o, _ = eng_o.serve(
        _mixed_requests(eng_o.cfg.vocab_size, glens, ggens))
    dt_o = time.perf_counter() - t0
    policy_stats = {}
    for policy in ("reserve", "grow"):
        eng = _mk_engine(sp, n_slots=4, max_seq=48, kv_layout="paged",
                         page_size=8, n_pages=13, prefill_chunk=8,
                         params=eng_o.params, kv_policy=policy)
        eng.serve(_mixed_requests(eng.cfg.vocab_size, glens[:2],
                                  [2, 2]))  # warm jits
        t0 = time.perf_counter()
        out, st = eng.serve(
            _mixed_requests(eng.cfg.vocab_size, glens, ggens))
        st["wall"] = time.perf_counter() - t0
        assert out == out_o, f"kv_policy={policy} diverged from the oracle"
        policy_stats[policy] = st
    res, gro = policy_stats["reserve"], policy_stats["grow"]
    n_tok = sum(ggens)
    assert gro["max_concurrent"] > res["max_concurrent"], (
        "grow-on-demand must admit strictly more concurrent requests "
        f"than reserve-on-admit at equal pool size: grow "
        f"{gro['max_concurrent']} vs reserve {res['max_concurrent']}")
    report("serve_paged_grow_vs_reserve", 0.0, {
        "parity": True,
        "pages_capacity": gro["pages_capacity"],
        "reserve_max_concurrent": res["max_concurrent"],
        "grow_max_concurrent": gro["max_concurrent"],
        "reserve_tok_s": round(n_tok / res["wall"], 1),
        "grow_tok_s": round(n_tok / gro["wall"], 1),
        "grow_preemptions": gro["preemptions"],
        "grow_grown_pages": gro["grown_pages"],
        "grow_prefix_hit_pages": gro["prefix_hit_pages"],
        "grow_cow_copies": gro["cow_copies"],
    })
    # -- chunked prefill bounds in-flight ITL under a long prompt -----------
    # A 96-token prompt arrives while short requests decode.  Monolithic
    # (contiguous) prefill stalls every in-flight slot for the whole
    # forward; page-aligned chunks bound the stall to one chunk per
    # iteration.  Acceptance (ISSUE 9): mixed-workload p95 inter-token
    # latency <= 1.5x the no-long-prompt paged baseline.
    LONG, SHORT = 96, 12
    short_lens = [SHORT] * 8
    mixed_lens = [SHORT] * 4 + [LONG] + [SHORT] * 3
    short_gens = [16] * 8
    mixed_gens = [16] * 4 + [8] + [16] * 3

    def _itl_run(engine, tel, lens, gens):
        engine.serve(_mixed_requests(engine.cfg.vocab_size, [SHORT, LONG],
                                     [2, 2], seed=1))  # warm all jits
        tel.registry.reset()
        _, stats = engine.serve(
            _mixed_requests(engine.cfg.vocab_size, lens, gens))
        h = tel.registry.histogram("serve.itl_s")
        return {"p95_ms": h.percentile(95.0) * 1e3,
                "max_ms": h.snapshot()["max"] * 1e3}, stats

    tel_p = Telemetry.on()
    eng_pg = _mk_engine(sp, n_slots=4, telemetry=tel_p, max_seq=128,
                        kv_layout="paged", page_size=8, prefill_chunk=8)
    base, _ = _itl_run(eng_pg, tel_p, short_lens, short_gens)
    mixed, stats_m = _itl_run(eng_pg, tel_p, mixed_lens, mixed_gens)
    tel_c = Telemetry.on()
    eng_ct = _mk_engine(sp, n_slots=4, telemetry=tel_c, max_seq=128)
    cont, _ = _itl_run(eng_ct, tel_c, mixed_lens, mixed_gens)
    ratio = mixed["p95_ms"] / base["p95_ms"]
    report("serve_paged_mixed_longprompt", 0.0, {
        "short_only_itl_p95_ms": round(base["p95_ms"], 2),
        "mixed_itl_p95_ms": round(mixed["p95_ms"], 2),
        "itl_p95_ratio": round(ratio, 2),
        "bound_1p5x_ok": bool(ratio <= 1.5),
        "mixed_itl_max_ms": round(mixed["max_ms"], 2),
        "contiguous_mixed_itl_p95_ms": round(cont["p95_ms"], 2),
        "contiguous_mixed_itl_max_ms": round(cont["max_ms"], 2),
        "prefill_chunks": stats_m["prefill_chunks"],
    })
    # -- telemetry overhead + schema-v2 latency/sparsity columns ------------
    # Telemetry-off rows above stay the trajectory baseline; this pass
    # re-runs the sparse-sparse continuous bench with full telemetry
    # (tracing, lifecycle records, realized-sparsity probe every 8 steps)
    # and reports overhead_pct against a telemetry-off engine.  Both
    # engines are fully warmed (the probed decode jit compiles on step 0,
    # the plain one on step 1+) and the runs are interleaved best-of-3 —
    # single short CPU runs are noisier than the overhead being measured.
    # The JSONL event log lands wherever REPRO_TELEMETRY_JSONL points
    # (CI's telemetry-smoke step validates it).
    off_eng = _mk_engine(VARIANTS[2][1], n_slots=4)
    off_eng.serve(_requests(off_eng, 1, gen=6))
    tel = Telemetry.on(jsonl_path=os.environ.get("REPRO_TELEMETRY_JSONL"),
                       sparsity_every=8)
    on_eng = _mk_engine(VARIANTS[2][1], n_slots=4, telemetry=tel)
    on_eng.serve(_requests(on_eng, 1, gen=6))
    tel.registry.reset()  # drop compile-laden warm-up from the percentiles

    def _tps(engine):
        out, stats = engine.serve(_requests(engine, 8))
        return sum(len(v) for v in out.values()) / stats["wall_s"]

    off_best, on_best = 0.0, 0.0
    for _ in range(3):
        off_best = max(off_best, _tps(off_eng))
        on_best = max(on_best, _tps(on_eng))
    snap = on_eng.metrics_snapshot()
    tel.close()
    row = {
        "telemetry_off_tok_s": round(off_best, 1),
        "telemetry_on_tok_s": round(on_best, 1),
        "telemetry_overhead_pct": round(
            100.0 * (1.0 - on_best / off_best), 1),
    }
    row.update(latency_columns(snap))
    row.update(sparsity_columns(snap))
    report("serve_sparse_sparse_telemetry_batch4", 0.0, row)


if __name__ == "__main__":
    import json

    def _report(name, us, derived=None):
        print(f"{name},{us:.2f},{json.dumps(derived or {}, sort_keys=True)}",
              flush=True)

    run(_report)
