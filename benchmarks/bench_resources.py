"""Paper Figures 15-18 analog: resource scaling of sparse-sparse conv
blocks with weight and activation sparsity.

FPGA resources (LUT/FF/URAM) have no TPU meaning; the graded analogs are
**HLO FLOPs** (compute resource), **bytes accessed** (memory-bandwidth
resource), and **parameter bytes** (capacity resource) of the paper's
1x1 [64:64] and 3x3 [64:64] conv blocks, swept over weight sparsity
(N in {4, 8, 16}) x activation sparsity (K in {16, 8, 4} of 64) — the same
grid as Figs 15-18.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import SparsityConfig
from repro.core.layers import packed_conv2d_apply, packed_conv2d_init
from repro.core.kwta import kwta
from repro.launch.hlo import cost_analysis_dict


def _analyze(kh, kw, n, k, spatial=10, batch=8):
    cfg = SparsityConfig(n=n, k_frac=k / 64, path="topk")
    params, _ = packed_conv2d_init(jax.random.PRNGKey(0), kh, kw, 64, 64, cfg)

    def fn(params, x):
        xs = kwta(x, k)  # channel k-WTA on the input (paper's Select)
        return packed_conv2d_apply(params, xs, cfg, kh, kw,
                                   x_is_sparse=True)

    x = jax.ShapeDtypeStruct((batch, spatial, spatial, 64), jnp.float32)
    compiled = jax.jit(fn).lower(params, x).compile()
    ca = cost_analysis_dict(compiled)
    pbytes = sum(v.size * v.dtype.itemsize
                 for v in jax.tree.leaves(params))
    return ca["flops"], ca["bytes accessed"], pbytes


def run(report):
    for kh in (1, 3):
        base = None
        for n in (4, 8, 16):
            for k in (16, 8, 4):
                flops, bytes_, pbytes = _analyze(kh, kh, n, k)
                if base is None:
                    base = (flops, bytes_, pbytes)
                report(f"fig{15 if kh == 1 else 16}_conv{kh}x{kh}_N{n}_K{k}",
                       0.0, {
                           "hlo_flops": int(flops),
                           "bytes_accessed": int(bytes_),
                           "param_bytes": int(pbytes),
                           "flops_vs_N4K16": round(base[0] / max(flops, 1), 2),
                           "param_cut_vs_N4K16": round(base[2] / pbytes, 2),
                       })
