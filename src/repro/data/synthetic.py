"""Deterministic synthetic data: stateless, resumable, shard-aware.

Every sample is a pure function of (seed, step, index) via a counter-based
hash (splitmix64) — no generator state to checkpoint.  Restoring a training
run at step S reproduces exactly the batches that would have followed S
(the checkpoint only needs the step counter), and each data shard draws
disjoint index ranges, so the pipeline scales to any number of hosts.

Streams:
  * ``lm_batch``      — language-model token streams with Zipf-ish marginals
    and a local bigram dependency (so cross-entropy has learnable signal).
  * ``gsc_batch``     — GSC-shaped (32x32x1) 'audio spectrogram' images with
    class-dependent frequency patterns (12 keyword classes), mirroring the
    paper's keyword-spotting task shape.
  * ``embed_batch``   — precomputed frontend embeddings (audio/vlm stubs).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _hash_uniform(seed: int, step: int, idx: np.ndarray) -> np.ndarray:
    """U[0,1) floats from (seed, step, flat index)."""
    base = np.uint64(seed) * np.uint64(0x100000001B3) + np.uint64(step)
    h = _splitmix64(idx.astype(np.uint64) ^ _splitmix64(
        np.full(idx.shape, base, np.uint64)))
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
             shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """Token batch (local shard): tokens + labels (next-token).

    Tokens follow a Zipf-like marginal with a deterministic bigram twist:
    t[i] depends on t[i-1] 25% of the time (so a model can reduce loss
    below the unigram entropy).
    """
    b_local = batch // n_shards
    idx = (np.arange(b_local * (seq + 1), dtype=np.uint64)
           + np.uint64(shard * b_local * (seq + 1)))
    u = _hash_uniform(seed, step, idx).reshape(b_local, seq + 1)
    # Zipf-ish marginal via u^3 concentration
    toks = np.minimum((u ** 3 * vocab).astype(np.int64), vocab - 1)
    # bigram dependency: 25% of positions copy a hash of the predecessor
    dep = _hash_uniform(seed + 1, step, idx).reshape(b_local, seq + 1)
    prev = np.roll(toks, 1, axis=1)
    linked = (prev * 31 + 7) % vocab
    toks = np.where(dep < 0.25, linked, toks)
    return {"tokens": toks[:, :seq].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def gsc_batch(seed: int, step: int, batch: int, n_classes: int = 12,
              shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """GSC-shaped synthetic keyword spectrograms (B, 32, 32, 1).

    Class c paints energy at 'formant' rows (frequencies) determined by c,
    plus noise — linearly separable enough that the paper's CNN trains to
    high accuracy in a few hundred steps on CPU."""
    b_local = batch // n_shards
    idx = (np.arange(b_local * 32 * 32, dtype=np.uint64)
           + np.uint64(shard * b_local * 32 * 32))
    noise = _hash_uniform(seed, step, idx).reshape(b_local, 32, 32, 1)
    labels = (_hash_uniform(seed + 2, step,
                            np.arange(b_local, dtype=np.uint64)
                            + np.uint64(shard * b_local))
              * n_classes).astype(np.int64)
    x = (noise - 0.5).astype(np.float32)
    rows = np.arange(32)
    for c in range(n_classes):
        f1, f2 = (3 * c + 2) % 32, (7 * c + 11) % 32
        pattern = ((rows[:, None] == f1) | (rows[:, None] == f2))
        mask = (labels == c)[:, None, None, None]
        x = x + 2.0 * mask * pattern[None, :, :, None].astype(np.float32)
    return {"x": x, "y": labels.astype(np.int32)}


def embed_batch(seed: int, step: int, batch: int, seq: int, d_model: int,
                vocab: int, shard: int = 0, n_shards: int = 1,
                prefix: int = 0) -> Dict[str, np.ndarray]:
    """Precomputed-frontend batches (audio 'embed' / vlm 'vision_prefix')."""
    b_local = batch // n_shards
    if prefix:  # vlm: text tokens + patch embeddings
        lm = lm_batch(seed, step, batch, seq - prefix, vocab, shard, n_shards)
        idx = (np.arange(b_local * prefix * d_model, dtype=np.uint64)
               + np.uint64(shard))
        pe = (_hash_uniform(seed + 3, step, idx)
              .reshape(b_local, prefix, d_model).astype(np.float32) - 0.5)
        return {"tokens": lm["tokens"], "labels": lm["labels"],
                "patch_embeds": pe}
    lm = lm_batch(seed, step, batch, seq, vocab, shard, n_shards)
    idx = (np.arange(b_local * seq * d_model, dtype=np.uint64)
           + np.uint64(shard))
    em = (_hash_uniform(seed + 4, step, idx)
          .reshape(b_local, seq, d_model).astype(np.float32) - 0.5)
    return {"embeds": em, "labels": lm["labels"]}


def batch_for(cfg, shape_or_none, step: int, seed: int = 0,
              batch: Optional[int] = None, seq: Optional[int] = None,
              shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """Dispatch on the model's frontend."""
    b = batch or shape_or_none.global_batch
    s = seq or shape_or_none.seq_len
    if cfg.frontend == "embed":
        return embed_batch(seed, step, b, s, cfg.d_model, cfg.padded_vocab,
                           shard, n_shards)
    if cfg.frontend == "vision_prefix":
        return embed_batch(seed, step, b, s, cfg.d_model, cfg.vocab_size,
                           shard, n_shards, prefix=cfg.n_prefix)
    return lm_batch(seed, step, b, s, cfg.vocab_size, shard, n_shards)
