"""Data substrate: deterministic synthetic streams + host prefetch."""

from .pipeline import Prefetcher
from .synthetic import batch_for, embed_batch, gsc_batch, lm_batch

__all__ = ["Prefetcher", "batch_for", "embed_batch", "gsc_batch", "lm_batch"]
