"""Host-side input pipeline: background prefetch + device placement.

A ``Prefetcher`` runs the (numpy, stateless) batch function for future
steps on a background thread, keeping ``depth`` batches ready, and places
them with the batch sharding so pjit consumes them without a host sync.
Because batches are pure functions of the step counter, the prefetcher has
no state to checkpoint and survives restarts for free (resume at step S
regenerates exactly batch S).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional

import jax
import numpy as np


class Prefetcher:
    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int, depth: int = 2, sharding=None):
        self._fn = batch_fn
        self._sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self._sharding[k])
                for k, v in batch.items()}

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                batch = self._fn(step)
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def get(self, expected_step: Optional[int] = None):
        step, batch = self._q.get()
        if expected_step is not None and step != expected_step:
            # a restart moved the step counter; regenerate synchronously
            batch = self._fn(expected_step)
            step = expected_step
        return step, self._place(batch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
