"""AdamW from scratch (no optax in this environment), pytree-native.

Production features:
  * integer/route leaves are transparently skipped (CS route tables live in
    the params pytree but are not trained),
  * moment dtype is configurable — ``bfloat16`` halves optimizer-state HBM
    (the 'optimizer-state compression' trick that lets qwen3-235B fit the
    assigned mesh, DESIGN.md §6; quality impact is the documented trade),
  * ZeRO-1: moment specs inherit the param specs, and the launcher
    additionally shards them over the DP axes when ``zero1=True``,
  * global-norm gradient clipping in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import is_spec as _is_spec


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def init_state(params, cfg: AdamWConfig) -> Dict:
    """Moments mirror float params; int leaves get empty placeholders."""

    def mk(p):
        if _is_float(p):
            return jnp.zeros(p.shape, cfg.moment_dtype)
        return jnp.zeros((), jnp.int32)  # placeholder for int leaves

    return {
        "mu": jax.tree.map(mk, params),
        "nu": jax.tree.map(mk, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs, params_shapes=None):
    """Moment sharding specs mirror the param specs (ZeRO extension is
    applied by the launcher on top)."""
    def leaf_spec(sp):
        return sp

    return {
        "mu": jax.tree.map(leaf_spec, param_specs,
                           is_leaf=_is_spec),
        "nu": jax.tree.map(leaf_spec, param_specs,
                           is_leaf=_is_spec),
        "step": (),
    }


def global_norm(grads) -> jax.Array:
    leaves = [g for g in jax.tree.leaves(grads) if _is_float(g)]
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))

    def f(g):
        return (g.astype(jnp.float32) * scale).astype(g.dtype) \
            if _is_float(g) else g

    return jax.tree.map(f, grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jax.Array = 1.0) -> Tuple[Dict, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        if not _is_float(p):
            return p, mu, nu
        g32 = g.astype(jnp.float32)
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        upd = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return p_new, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                 "nu": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm}
