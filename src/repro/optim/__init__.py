"""Optimizer substrate: AdamW (+ compressed moments), schedules, int8
error-feedback gradient compression."""

from .adamw import (AdamWConfig, apply_updates, clip_by_global_norm,
                    global_norm, init_state, state_specs)
from .compression import (dequantize_int8, init_residuals,
                          make_compressed_grad_sync, quantize_int8)
from .schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "apply_updates", "clip_by_global_norm",
           "global_norm", "init_state", "state_specs", "dequantize_int8",
           "init_residuals", "make_compressed_grad_sync", "quantize_int8",
           "constant", "warmup_cosine"]
