"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup_steps: int, total_steps: int,
                  min_frac: float = 0.1):
    """Linear warmup then cosine decay to ``min_frac`` of peak."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step, **_):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))
