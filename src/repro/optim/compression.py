"""Int8 error-feedback gradient compression for cross-pod synchronization.

At multi-pod scale the pod-to-pod (DCI) links are the slowest hop of the
gradient all-reduce.  This module implements the classic error-feedback
scheme [1-bit SGD / EF-SGD]: quantize (grad + residual) to int8 with a
per-tensor scale, all-reduce the int8 payload over the ``pod`` axis,
dequantize, and carry the quantization error into the next step's residual.
Payload shrinks 4x vs fp32 (2x vs bf16); the residual guarantees the
*accumulated* update is unbiased.

Composition contract (DESIGN.md §6): this is applied under ``shard_map``
over the ``pod`` axis on grads that are fully-reduced *within* each pod
(the plain in-pod psum stays uncompressed — intra-pod ICI is fast).  The
launcher enables it only on meshes where the model axes do not interact
with the pod axis (pure-DP pod usage), which is the production layout.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ef_psum_leaf(g, resid, axis: str, n_pods: int):
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return g, resid
    comp_in = g.astype(jnp.float32) + resid
    q, scale = quantize_int8(comp_in)
    sent = dequantize_int8(q, scale)
    new_resid = comp_in - sent
    # int8 payloads all-reduce in int32 to avoid overflow; scales reduce too.
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    # each pod used its own scale; reduce the dequantized mean exactly by
    # summing per-pod contributions: psum(q*scale) == psum over scaled q.
    g_sum = jax.lax.psum(sent, axis)
    del q_sum  # int payload is what goes on the wire; value path uses g_sum
    return (g_sum / n_pods).astype(g.dtype), new_resid


def make_compressed_grad_sync(mesh: Mesh, axis: str = "pod"):
    """Returns sync(grads, residuals) -> (synced, new_residuals), a
    shard_map'd cross-pod mean with int8 error feedback.

    Per-pod grads enter with replicated specs (each pod holds its own full
    copy — `check_vma=False` because values legitimately differ across the
    pod axis before the reduction).  Residuals are *per-pod state*: they
    carry a leading ``n_pods`` dim sharded over the pod axis
    (:func:`init_residuals`).
    """
    n_pods = mesh.shape[axis]

    def sync_local(grads, resids):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(resids)
        out = []
        for g, r in zip(flat_g, flat_r):
            if jnp.issubdtype(g.dtype, jnp.floating):
                g_new, r_new = _ef_psum_leaf(g, r[0], axis, n_pods)
                out.append((g_new, r_new[None]))
            else:
                out.append((g, r))
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    g_spec, r_spec = P(), P(axis)

    def sync(grads, resids):
        from repro.sharding.context import shard_map
        return shard_map(
            sync_local, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: g_spec, grads),
                      jax.tree.map(lambda _: r_spec, resids)),
            out_specs=(jax.tree.map(lambda _: g_spec, grads),
                       jax.tree.map(lambda _: r_spec, resids)),
            check_vma=False,
        )(grads, resids)

    return sync


def init_residuals(grads_like, n_pods: int):
    """Per-pod residual state: leading dim n_pods, sharded over 'pod'."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_pods, *g.shape), jnp.float32)
        if jnp.issubdtype(g.dtype, jnp.floating)
        else jnp.zeros((n_pods,), jnp.int32),
        grads_like)
