"""Decoder LM assembled from config-driven blocks.

The layer stack is a ``lax.scan`` over *superblocks* (one repetition of
``cfg.block_pattern``) with stacked params — compile time and HLO size stay
O(pattern), not O(n_layers).  Heterogeneous stacks (xLSTM's mLSTM+sLSTM,
zamba2's mamba2+shared-attention) are expressed inside the pattern;
zamba2's weight-shared attention block lives *outside* the scanned params
(a closure constant — the same weights at every invocation, which is
exactly the Zamba trick).

Block kinds:
  attn        — (MLA when cfg.use_mla) attention + FFN or MoE, pre-norm.
  mamba2      — Mamba-2 mixer (chunked SSD).
  mlstm/slstm — xLSTM mixers.
  shared_attn — weight-shared attention + FFN block (zamba2).

Two entry points per workload:
  :func:`loss_fn` / :func:`forward` — training & prefill (full sequence).
  :func:`serve_step` + :func:`init_cache` — one-token decode with caches
  (KV for attention; O(1) state for SSM blocks — the `long_500k` path).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.obs import sparsity as obs_sparsity
from repro.sharding.context import constrain, is_spec as _is_spec
from . import attention as A
from . import ssm as S
from .common import (cross_entropy, dtype_of, embedding_init, rmsnorm_apply,
                     rmsnorm_init)
from .ffn import ffn_apply, ffn_init
from .moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# Block init/apply/decode dispatch
# ---------------------------------------------------------------------------

def _block_init(kind: str, key, cfg):
    ks = jax.random.split(key, 4)
    if kind in ("attn", "shared_attn"):
        p, s = {}, {}
        p["norm1"], s["norm1"] = rmsnorm_init(cfg.d_model)
        if cfg.use_mla:
            p["mixer"], s["mixer"] = A.mla_init(ks[0], cfg)
        else:
            p["mixer"], s["mixer"] = A.gqa_init(ks[0], cfg)
        p["norm2"], s["norm2"] = rmsnorm_init(cfg.d_model)
        if cfg.is_moe and kind == "attn":
            p["moe"], s["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff,
                                          cfg.n_experts, cfg.n_shared_experts,
                                          cfg.act, cfg.ffn_sparsity)
        elif cfg.d_ff > 0:
            p["ffn"], s["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff,
                                          cfg.ffn_sparsity, cfg.act)
        return p, s
    if kind == "mamba2":
        p, s = {}, {}
        p["norm"], s["norm"] = rmsnorm_init(cfg.d_model)
        p["mixer"], s["mixer"] = S.mamba2_init(ks[0], cfg)
        return p, s
    if kind == "mlstm":
        p, s = {}, {}
        p["norm"], s["norm"] = rmsnorm_init(cfg.d_model)
        p["mixer"], s["mixer"] = S.mlstm_init(ks[0], cfg)
        return p, s
    if kind == "slstm":
        p, s = {}, {}
        p["norm"], s["norm"] = rmsnorm_init(cfg.d_model)
        p["mixer"], s["mixer"] = S.slstm_init(ks[0], cfg)
        return p, s
    raise ValueError(f"unknown block kind {kind}")


def _block_apply(kind: str, params, x, cfg, positions):
    """Full-sequence forward. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "shared_attn"):
        h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
        if cfg.use_mla:
            h = A.mla_apply(params["mixer"], h, cfg, positions)
        else:
            h = A.gqa_apply(params["mixer"], h, cfg, positions)
        x = x + h
        h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if "moe" in params:
            h, aux = moe_apply(params["moe"], h, cfg, cfg.ffn_sparsity)
            x = x + h
        elif "ffn" in params:
            x = x + ffn_apply(params["ffn"], h, cfg.ffn_sparsity, cfg.act)
        return x, aux
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps)
    mixer = {"mamba2": S.mamba2_apply, "mlstm": S.mlstm_apply,
             "slstm": S.slstm_apply}[kind]
    return x + mixer(params["mixer"], h, cfg), aux


def _block_cache_init(kind: str, cfg, batch: int, max_seq: int, dtype):
    if kind in ("attn", "shared_attn"):
        if cfg.use_mla:
            return A.mla_cache_init(cfg, batch, max_seq, dtype), \
                A.mla_cache_specs()
        return A.gqa_cache_init(cfg, batch, max_seq, dtype), \
            A.gqa_cache_specs(cfg)
    init = {"mamba2": S.mamba2_cache_init, "mlstm": S.mlstm_cache_init,
            "slstm": S.slstm_cache_init}[kind]
    specs = {"mamba2": S.mamba2_cache_specs, "mlstm": S.mlstm_cache_specs,
             "slstm": S.slstm_cache_specs}[kind]
    return init(cfg, batch, dtype), specs()


def _block_decode(kind: str, params, x, cfg, cache, pos, pages=None):
    """One-token step. Returns (x, new_cache).  ``pages`` (the paged KV
    layout's per-slot page table) is attention-only: SSM blocks keep O(1)
    recurrence state and have no per-position rows to page."""
    if kind in ("attn", "shared_attn"):
        h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
        dec = A.mla_decode if cfg.use_mla else A.gqa_decode
        h, new_cache = dec(params["mixer"], h, cfg, cache, pos,
                           pages=pages)
        x = x + h
        h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if "moe" in params:
            h, _ = moe_apply(params["moe"], h, cfg, cfg.ffn_sparsity)
            x = x + h
        elif "ffn" in params:
            x = x + ffn_apply(params["ffn"], h, cfg.ffn_sparsity, cfg.act)
        return x, new_cache
    if pages is not None:
        raise NotImplementedError(
            f"paged KV layout not implemented for block kind {kind!r} "
            "(SSM decode state has no sequence axis to page)")
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps)
    dec = {"mamba2": S.mamba2_decode, "mlstm": S.mlstm_decode,
           "slstm": S.slstm_decode}[kind]
    h, new_cache = dec(params["mixer"], h, cfg, cache, pos)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_model(key, cfg) -> Tuple[Dict, Dict]:
    """Returns (params, specs).  params["units"] leaves have leading dim
    n_units (scanned); params["shared"] (if any) is the zamba2 shared
    block."""
    keys = jax.random.split(key, cfg.n_units + 3)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = embedding_init(
        keys[0], cfg.padded_vocab, cfg.d_model)

    has_shared = "shared_attn" in cfg.block_pattern
    if has_shared:
        params["shared"], specs["shared"] = _block_init("shared_attn",
                                                        keys[1], cfg)

    def unit_init(key):
        ks = jax.random.split(key, len(cfg.block_pattern))
        p, s = {}, {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "shared_attn":
                continue  # weights live in params["shared"]
            p[f"b{i}"], s[f"b{i}"] = _block_init(kind, ks[i], cfg)
        return p, s

    unit_ps = [unit_init(keys[2 + u]) for u in range(cfg.n_units)]
    params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *
                                   [p for p, _ in unit_ps])
    # specs: identical across units; prepend the (unsharded) layer axis
    unit_spec = unit_ps[0][1]
    specs["units"] = jax.tree.map(
        lambda sp: (None,) + tuple(sp), unit_spec,
        is_leaf=_is_spec)

    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        from .common import normal_init
        params["head"] = {"table": normal_init(keys[-1],
                                               (cfg.padded_vocab, cfg.d_model),
                                               0.02)}
        specs["head"] = {"table": ("vocab", "embed")}
    return params, specs


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg, ct):
    """Token/frontend embedding. Returns (x, loss_mask)."""
    if cfg.frontend == "embed":
        x = batch["embeds"].astype(ct)  # (B, S, D) precomputed (stub)
        mask = None
    elif cfg.frontend == "vision_prefix":
        tok = jnp.take(params["embed"]["table"].astype(ct),
                       batch["tokens"], axis=0)
        x = jnp.concatenate([batch["patch_embeds"].astype(ct), tok], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(batch["patch_embeds"].shape[:2], bool),
             jnp.ones(batch["tokens"].shape, bool)], axis=1)
    else:
        x = jnp.take(params["embed"]["table"].astype(ct),
                     batch["tokens"], axis=0)
        mask = None
    return constrain(x, "batch", "seq", None), mask


def forward(params, batch, cfg) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    ct = dtype_of(cfg.compute_dtype)
    x, _ = _embed_inputs(params, batch, cfg, ct)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared = params.get("shared")

    def unit_fn(carry, unit_params):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            apply = lambda p, x, k=kind: _block_apply(k, p, x, cfg, positions)
            if cfg.remat:
                # block-granular remat: backward holds at most one block's
                # intermediates (the scan carry is the remat stack)
                apply = jax.checkpoint(apply)
            with jax.named_scope(f"b{i}_{kind}"):
                x, a = apply(p, x)
            aux = aux + a
        x = constrain(x, "batch", "seq", None)
        return (x, aux), None

    (x, aux), _ = lax.scan(unit_fn, (x, jnp.zeros((), jnp.float32)),
                           params["units"])
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"] if cfg.tie_embeddings else params["head"])["table"]
    logits = x @ table.astype(ct).T
    return constrain(logits, "batch", "seq", "vocab"), aux


def loss_fn(params, batch, cfg):
    """Next-token LM loss. batch: tokens/embeds (+ labels)."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.frontend == "vision_prefix":
        # logits cover [prefix + text]; predict text tokens only
        n_pre = batch["patch_embeds"].shape[1]
        logits = logits[:, n_pre:]
    lm = cross_entropy(logits[:, :-1], labels[:, 1:])
    loss = lm + cfg.router_aux_weight * aux
    return loss, {"loss": loss, "lm_loss": lm, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving (one-token decode with caches)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int):
    """Stacked per-unit caches: each leaf has leading dim n_units."""
    ct = dtype_of(cfg.compute_dtype)
    unit_cache, unit_specs = {}, {}
    for i, kind in enumerate(cfg.block_pattern):
        c, sp = _block_cache_init(kind, cfg, batch, max_seq, ct)
        unit_cache[f"b{i}"], unit_specs[f"b{i}"] = c, sp
    cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_units, *x.shape)), unit_cache)
    specs = jax.tree.map(
        lambda sp: (None,) + tuple(sp), unit_specs,
        is_leaf=_is_spec)
    return cache, specs


def init_paged_cache(cfg, n_pages: int, page_size: int):
    """Stacked per-unit PAGED caches: every attention leaf is a page
    pool ``(n_units, n_pages, page_size, ...)`` addressed through the
    per-slot page tables that :func:`serve_step` / :func:`prefill_chunk`
    take as ``pages`` (see :mod:`repro.runtime.kvcache`).  Pool geometry
    replaces the contiguous ``(batch, kvseq)`` axes, so the same
    per-block inits produce the leaves; the sharding spec replicates the
    pool axes (pages are not sharded — page ids must stay global).

    Attention-only block patterns (paged layout pages per-position KV
    rows; SSM decode state is O(1) and has nothing to page)."""
    if not all(k in ("attn", "shared_attn") for k in cfg.block_pattern):
        raise NotImplementedError(
            "paged KV layout requires an attention-only block pattern, "
            f"got {cfg.block_pattern}")
    ct = dtype_of(cfg.compute_dtype)
    unit_cache, unit_specs = {}, {}
    for i, kind in enumerate(cfg.block_pattern):
        c, sp = _block_cache_init(kind, cfg, n_pages, page_size, ct)
        unit_cache[f"b{i}"] = c
        unit_specs[f"b{i}"] = jax.tree.map(
            lambda s: (None, None) + tuple(s)[2:], sp, is_leaf=_is_spec)
    cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_units, *x.shape)), unit_cache)
    specs = jax.tree.map(
        lambda sp: (None,) + tuple(sp), unit_specs,
        is_leaf=_is_spec)
    return cache, specs


def copy_cache_page(cache, src, dst):
    """Copy physical page ``src``'s rows over page ``dst`` in every pool
    leaf of an :func:`init_paged_cache` cache — the device half of a
    copy-on-write break (the allocator already swapped ``dst`` into the
    writer's chain; this materialises the shared rows there before the
    writer's next scatter lands).  src/dst: scalar int32 page ids; leaf
    layout ``(n_units, n_pages, page_size, ...)``."""
    return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]),
                        cache)


def supports_fused_prefill(cfg) -> bool:
    """Fused bulk-cache prefill exists for attention blocks; SSM/hybrid
    patterns fall back to stepwise prefill (their decode state is the
    *final* recurrence state, not per-position rows)."""
    return all(k in ("attn", "shared_attn") for k in cfg.block_pattern)


def _block_prefill(kind: str, params, x, cfg, positions, max_seq: int):
    """Full-sequence forward that also emits the block's decode cache in
    bulk. Returns (x, cache)."""
    if kind not in ("attn", "shared_attn"):
        raise NotImplementedError(
            f"fused prefill not implemented for block kind {kind!r}; "
            "use the stepwise prefill path")
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    pre = A.mla_prefill if cfg.use_mla else A.gqa_prefill
    h, cache = pre(params["mixer"], h, cfg, positions, max_seq)
    x = x + h
    h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
    if "moe" in params:
        h, _ = moe_apply(params["moe"], h, cfg, cfg.ffn_sparsity)
        x = x + h
    elif "ffn" in params:
        x = x + ffn_apply(params["ffn"], h, cfg.ffn_sparsity, cfg.act)
    return x, cache


def prefill(params, batch, cfg, max_seq: int):
    """Fused full-sequence prefill: ONE compiled call per prompt.

    Runs the full forward over the prompt (B, S) while writing every
    block's KV cache in bulk — rows [0, S) of a cache padded to
    ``max_seq`` (rows >= S are zeros and are overwritten by decode before
    any read; the validity mask in the decode steps never looks past the
    current position).  The cache pytree matches :func:`init_cache`
    exactly (leaves stacked over n_units), so the serving engine can
    insert it into a slot of the live batch cache and hand off to
    :func:`serve_step`.

    Returns (logits (B, S, vocab), cache).
    """
    ct = dtype_of(cfg.compute_dtype)
    x, _ = _embed_inputs(params, batch, cfg, ct)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared = params.get("shared")

    def unit_fn(x, unit_params):
        caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            with jax.named_scope(f"b{i}_{kind}"), \
                    obs_sparsity.observe_site(f"b{i}"):
                x, caches[f"b{i}"] = _block_prefill(kind, p, x, cfg,
                                                    positions, max_seq)
        # Same capture handoff as serve_step (empty tuple when inactive).
        return x, (caches, obs_sparsity.drain_pending())

    x, (cache, sparsity_aux) = lax.scan(unit_fn, x, params["units"])
    obs_sparsity.emit_stacked(sparsity_aux)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"] if cfg.tie_embeddings else params["head"])["table"]
    logits = x @ table.astype(ct).T
    return constrain(logits, "batch", "seq", "vocab"), cache


def serve_step(params, cache, batch, pos, cfg, pages=None):
    """Decode one token given caches of past state.

    batch: {"tokens": (B, 1)} (or {"embeds": (B, 1, D)}).
    pos: scalar position (static batch) or (B,) per-slot positions
    (continuous batching).
    pages: optional (B, n_blocks) int32 per-slot page tables — the cache
    leaves are then the :func:`init_paged_cache` pools and every
    attention read/write goes through the page indirection (same math,
    same mask; token-exact vs the contiguous layout).
    Returns (logits (B, vocab), new_cache).

    Sparse-sparse decode runs the fused pipeline per layer: the FFN's
    k-WTA Select hands its (vals, idx) support straight to the down
    projection (one top_k per sparse layer), which contracts the whole
    decode batch in one ``topk_gather`` launch when the executor
    (``cfg.ffn_sparsity.use_pallas``) engages the Pallas path.
    """
    ct = dtype_of(cfg.compute_dtype)
    if cfg.frontend == "embed":
        x = batch["embeds"].astype(ct)
    else:
        x = jnp.take(params["embed"]["table"].astype(ct), batch["tokens"],
                     axis=0)
    shared = params.get("shared")

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            with jax.named_scope(f"b{i}_{kind}"), \
                    obs_sparsity.observe_site(f"b{i}"):
                x, new_cache[f"b{i}"] = _block_decode(
                    kind, p, x, cfg, unit_cache[f"b{i}"], pos, pages)
        # Realized-sparsity capture handoff: when the serving engine's
        # probed step is tracing, the winner sets observed inside this
        # body leave the scan as stacked (n_units, ...) outputs.  With no
        # active capture this is the empty tuple — zero extra leaves, the
        # staged jaxpr is unchanged (asserted by tests/test_obs.py).
        return x, (new_cache, obs_sparsity.drain_pending())

    x, (new_cache, sparsity_aux) = lax.scan(unit_fn, x,
                                            (params["units"], cache))
    obs_sparsity.emit_stacked(sparsity_aux)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"] if cfg.tie_embeddings else params["head"])["table"]
    logits = (x @ table.astype(ct).T)[:, 0]
    return constrain(logits, "batch", "vocab"), new_cache


def _block_chunk_prefill(kind: str, params, x, cfg, cache, pages,
                         pos_start, chunk_len):
    """Chunked-prefill step of one block over the paged cache.
    Returns (x, new_cache)."""
    if kind not in ("attn", "shared_attn"):
        raise NotImplementedError(
            f"chunked prefill not implemented for block kind {kind!r}")
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    pre = A.mla_chunk_prefill if cfg.use_mla else A.gqa_chunk_prefill
    h, new_cache = pre(params["mixer"], h, cfg, cache, pages, pos_start,
                       chunk_len)
    x = x + h
    h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
    if "moe" in params:
        h, _ = moe_apply(params["moe"], h, cfg, cfg.ffn_sparsity)
        x = x + h
    elif "ffn" in params:
        x = x + ffn_apply(params["ffn"], h, cfg.ffn_sparsity, cfg.act)
    return x, new_cache


def prefill_chunk(params, cache, batch, pos_start, chunk_len, cfg, pages):
    """Forward ONE page-aligned prompt chunk of ONE slot through every
    block, scattering its KV rows into the slot's page chains (the paged
    layout's incremental prefill — long prompts run as a sequence of
    these interleaved with decode steps instead of one monolithic
    :func:`prefill` call).

    batch: {"tokens": (1, C)}; pages: (1, n_blocks) int32 — the
    prefilling slot's page table; pos_start / chunk_len: traced scalars,
    so chunks of any true length share one compile per C bucket (rows
    past ``chunk_len`` are bucket padding: their KV sinks to the null
    page and their logits are garbage the engine ignores).
    Returns (logits (1, C, vocab), new_cache) with pool-shaped leaves.
    """
    ct = dtype_of(cfg.compute_dtype)
    if cfg.frontend == "embed":
        x = batch["embeds"].astype(ct)
    else:
        x = jnp.take(params["embed"]["table"].astype(ct), batch["tokens"],
                     axis=0)
    shared = params.get("shared")

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            with jax.named_scope(f"b{i}_{kind}"), \
                    obs_sparsity.observe_site(f"b{i}"):
                x, new_cache[f"b{i}"] = _block_chunk_prefill(
                    kind, p, x, cfg, unit_cache[f"b{i}"], pages,
                    pos_start, chunk_len)
        # Same capture handoff as serve_step (empty tuple when inactive).
        return x, (new_cache, obs_sparsity.drain_pending())

    x, (new_cache, sparsity_aux) = lax.scan(unit_fn, x,
                                            (params["units"], cache))
    obs_sparsity.emit_stacked(sparsity_aux)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"] if cfg.tie_embeddings else params["head"])["table"]
    logits = x @ table.astype(ct).T
    return constrain(logits, "batch", "seq", "vocab"), new_cache


def unit_step_fn(cfg):
    """A single-superblock forward for per-layer cost accounting (the
    roofline reads FLOPs from this, times n_units — lax.scan bodies are
    counted once by XLA's cost analysis; see launch/roofline.py)."""

    def fn(unit_params, shared, x, positions):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            with jax.named_scope(f"b{i}_{kind}"):
                x, a = _block_apply(kind, p, x, cfg, positions)
            aux += a
        return x, aux

    return fn
