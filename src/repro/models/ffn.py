"""Feed-forward blocks: dense SwiGLU/GELU and the complementary-sparse
sparse-sparse FFN (the paper's technique applied to Transformer linear
layers, their §6.4 future direction).

Sparse-sparse FFN dataflow (mirrors paper Fig. 8a at layer granularity):

    h   = act(W_gate x) * (W_up x)        (packed CS weights: sparse-dense)
    h_s = k-WTA(h)                        (Select — the layer's ONE top_k;
                                           its (vals, idx) support is handed
                                           straight to the down projection)
    y   = W_down h_s                      (packed CS; with the k-sparse
                                           input this is the sparse-sparse
                                           Multiply-Route-Sum — dispatched
                                           to the topk path when B·K < d_ff,
                                           consuming the handed-off support
                                           so no second top_k runs)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import SparsityConfig
from repro.core.layers import (apply_kwta, linear_apply, linear_init,
                               packed_linear_apply, packed_linear_init)
from repro.obs.sparsity import observe_site
from repro.sharding.context import constrain


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def ffn_init(key, d_model: int, d_ff: int, cfg_sp: SparsityConfig,
             act: str = "silu"):
    """SwiGLU (silu) or plain (gelu/relu) FFN; packed when cfg_sp.n > 1."""
    ks = jax.random.split(key, 3)
    gated = act == "silu"
    params, specs = {}, {}

    def mk(key, d_in, d_out, out_axis, seed):
        if cfg_sp.weight_sparse and d_in % cfg_sp.n == 0 and d_out % cfg_sp.n == 0:
            return packed_linear_init(key, d_in, d_out, cfg_sp, bias=False,
                                      seed=seed, out_axis=out_axis)
        return linear_init(key, d_in, d_out, bias=False, out_axis=out_axis)

    params["up"], specs["up"] = mk(ks[0], d_model, d_ff, "mlp", 21)
    if gated:
        params["gate"], specs["gate"] = mk(ks[1], d_model, d_ff, "mlp", 22)
    params["down"], specs["down"] = mk(ks[2], d_ff, d_model, "embed", 23)
    return params, specs


def _apply_one(p, x, sp: SparsityConfig, x_is_sparse=False, support=None):
    if "packed" in p:
        return packed_linear_apply(p, x, sp, x_is_sparse=x_is_sparse,
                                   support=support)
    return linear_apply(p, x)


def ffn_apply(params, x, cfg_sp: SparsityConfig, act: str = "silu"):
    a = _act(act)
    with jax.named_scope("ffn_up"):
        up = _apply_one(params["up"], x, cfg_sp)
    if "gate" in params:
        with jax.named_scope("ffn_gate"):
            h = a(_apply_one(params["gate"], x, cfg_sp)) * up
    else:
        h = a(up)
    h = constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("mlp",)))
    # Select (k-WTA) — identity when disabled. The winner support is handed
    # to the down projection so the sparse-sparse path never re-derives it.
    with jax.named_scope("ffn_kwta"), observe_site("ffn"):
        h, support = apply_kwta(h, cfg_sp, return_support=True)
    with jax.named_scope("ffn_down"):
        return _apply_one(params["down"], h, cfg_sp,
                          x_is_sparse=cfg_sp.activation_sparse,
                          support=support)
