"""Model zoo: decoder LMs (dense/MoE/SSM/hybrid/audio/VLM backbones) and the
paper's GSC CNN, assembled from config-driven blocks."""

from . import attention, ffn, gsc_cnn, moe, ssm, transformer
from .transformer import (forward, init_cache, init_model, loss_fn,
                          param_count, serve_step)

__all__ = ["attention", "ffn", "gsc_cnn", "moe", "ssm", "transformer",
           "forward", "init_cache", "init_model", "loss_fn", "param_count",
           "serve_step"]
