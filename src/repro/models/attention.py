"""Attention blocks: GQA with RoPE (+ blockwise 'flash' softmax for long
prefill), MLA (DeepSeek-V2 latent compression), and KV-cache decode steps.

Conventions:
  x          (B, S, D)
  kv cache   {"k": (B, Smax, Hkv, Dh), "v": ..., } + position carried by the
             caller; cache seq axis uses logical axis "kvseq" (SP, §6).
  Projections may be complementary-sparse (cfg.proj_sparsity) — the paper's
  §6.4 'apply Complementary Sparsity to Transformers'.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.api import SparsityConfig
from repro.core.layers import (apply_kwta, linear_apply, linear_init,
                               packed_linear_apply, packed_linear_init)
from repro.obs.sparsity import observe_site
from repro.runtime.kvcache import paged_view, paged_write_chunk, \
    paged_write_rows
from repro.sharding.context import constrain
from .common import apply_rope, normal_init


def _proj_init(key, d_in, d_out, sp: SparsityConfig, out_axis, name_seed):
    """Dense or CS-packed projection depending on cfg.proj_sparsity."""
    if sp.weight_sparse and d_in % sp.n == 0 and d_out % sp.n == 0:
        return packed_linear_init(key, d_in, d_out, sp, bias=False,
                                  seed=name_seed, out_axis=out_axis)
    p, s = linear_init(key, d_in, d_out, bias=False, out_axis=out_axis)
    return p, s


def _proj_apply(params, x, sp: SparsityConfig, x_is_sparse=False,
                support=None):
    if "packed" in params:
        return packed_linear_apply(params, x, sp, x_is_sparse=x_is_sparse,
                                   support=support)
    return linear_apply(params, x)


def _o_proj(params, out_flat, sp: SparsityConfig):
    """Output projection with the sparse-activation handoff: when the
    projection family is activation-sparse (cfg.proj_sparsity.k_frac), the
    attention output goes through k-WTA and its winner support is handed to
    the CS-packed o-projection — the same one-Select-per-layer pipeline as
    the FFN down projection (paper Fig. 8a applied to §6.4's Transformer
    projections)."""
    with jax.named_scope("o_proj"), observe_site("o_proj"):
        if sp.activation_sparse:
            out_flat, support = apply_kwta(out_flat, sp, return_support=True)
            return _proj_apply(params, out_flat, sp, x_is_sparse=True,
                               support=support)
        return _proj_apply(params, out_flat, sp)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    h, hkv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    hp = cfg.padded_heads
    ks = jax.random.split(key, 4)
    sp = cfg.proj_sparsity
    q, qs = _proj_init(ks[0], d, h * dh, sp, "heads", 11)
    k, ks_ = _proj_init(ks[1], d, hkv * dh, sp, "kv", 12)
    v, vs = _proj_init(ks[2], d, hkv * dh, sp, "kv", 13)
    # o-proj rows for padded dummy heads exist but only ever see zeros
    o, os_ = _proj_init(ks[3], hp * dh, d, sp, "embed", 14)
    return ({"q": q, "k": k, "v": v, "o": o},
            {"q": qs, "k": ks_, "v": vs, "o": os_})


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def _pad_heads(x, h_pad):
    """Pad the head axis (-2) with zero heads up to h_pad (TP
    divisibility; DESIGN.md §6). GQA grouping is preserved because padding
    happens *after* the kv repeat."""
    h = x.shape[-2]
    if h_pad <= h:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-2] = (0, h_pad - h)
    return jnp.pad(x, pad)


def _mask_dummy_heads(out, cfg):
    """Zero the padded heads' outputs so the o-projection sees the exact
    n_heads function (dummy heads attend uniformly — must not leak)."""
    h, hp = cfg.n_heads, cfg.padded_heads
    if hp == h:
        return out
    mask = (jnp.arange(hp) < h).astype(out.dtype)
    return out * mask[..., :, None]


def _causal_attn(q, k, v, scale):
    """Materialized causal attention (short seq). q/k/v: (B, S, H, Dh)."""
    s_q, s_k = q.shape[1], k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = np.tril(np.ones((s_q, s_k), bool), k=s_k - s_q)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_attn(q, k, v, scale, block: int, unroll: bool = False):
    """Blockwise (online-softmax) causal attention: O(S·block) memory.

    Scans over KV chunks carrying (acc, row_max, row_sum). Used whenever
    S_kv exceeds `block` (32k prefill would otherwise materialize an
    S², per-head score tensor).
    """
    b, s_q, h, dh = q.shape
    dv = v.shape[-1]  # may differ from dh (MLA: rope-extended queries)
    s_k = k.shape[1]
    nblk = s_k // block
    q32 = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(s_q)

    @jax.checkpoint  # flash-style backward: recompute scores per block
    def body(carry, blk):
        acc, m, l = carry
        kb, vb, kb_start = blk
        scores = jnp.einsum("bqhd,bkhd->bhqk", q32, kb.astype(jnp.float32))
        k_pos = kb_start + jnp.arange(block)
        mask = q_pos[:, None] + (s_k - s_q) >= k_pos[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    kb = k.reshape(b, nblk, block, h, dh).swapaxes(0, 1)
    vb = v.reshape(b, nblk, block, h, dv).swapaxes(0, 1)
    starts = jnp.arange(nblk) * block
    init = (jnp.zeros((b, h, s_q, dv), jnp.float32),
            jnp.full((b, h, s_q), -jnp.inf),
            jnp.zeros((b, h, s_q), jnp.float32))
    (acc, m, l), _ = lax.scan(body, init, (kb, vb, starts),
                           unroll=nblk if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, S, H, Dh)


def _gqa_forward(params, x, cfg, positions, quantize_kv: bool = False):
    """Full causal self-attention. Returns (y, k_rows, v_rows) where
    k_rows/v_rows are the roped true-head K/V — exactly what the decode
    cache stores per position (the fused-prefill bulk write).

    ``quantize_kv`` (int8 cache prefill): attention reads the
    quantize→dequantize roundtrip of K/V instead of the exact rows —
    the cache *representation* — so fused prefill sees exactly what
    chunked prefill and every later decode step will read back, keeping
    the contiguous engine a token-exact oracle for the paged one.
    ``k_rows``/``v_rows`` stay exact: storage quantizes the originals.
    """
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hp = cfg.padded_heads
    sp = cfg.proj_sparsity
    q = _split_heads(_proj_apply(params["q"], x, sp), h, dh)
    k = _split_heads(_proj_apply(params["k"], x, sp), hkv, dh)
    v = _split_heads(_proj_apply(params["v"], x, sp), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_rows, v_rows = k, v
    if quantize_kv:
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        k = kq.astype(x.dtype) * ks[..., None].astype(x.dtype)
        v = vq.astype(x.dtype) * vs[..., None].astype(x.dtype)
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    q, k, v = (_pad_heads(t, hp) for t in (q, k, v))
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    scale = 1.0 / np.sqrt(dh)
    if x.shape[1] > cfg.flash_block:
        out = _flash_attn(q, k, v, scale, cfg.flash_block,
                          unroll=cfg.unroll_inner)
    else:
        out = _causal_attn(q, k, v, scale)
    out = constrain(out, "batch", "seq", "heads", None)
    out = _mask_dummy_heads(out, cfg)
    y = _o_proj(params["o"], out.reshape(*x.shape[:-1], hp * dh), sp)
    return y, k_rows, v_rows


def gqa_apply(params, x, cfg, positions):
    """Training/prefill forward (full causal self-attention)."""
    return _gqa_forward(params, x, cfg, positions)[0]


def _pad_seq(x, max_seq: int):
    """Zero-pad the sequence axis (1) out to ``max_seq``."""
    s = x.shape[1]
    if s >= max_seq:
        return x[:, :max_seq]
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, max_seq - s)
    return jnp.pad(x, pad)


def gqa_prefill(params, x, cfg, positions, max_seq: int):
    """Fused full-sequence prefill: one forward over the whole prompt that
    also emits the decode cache in bulk (rows [0, S) written at once).
    Rows >= S are scratch — zeros here, but pad-token K/V when the caller
    bucket-pads the prompt — and are only safe because decode overwrites
    row ``pos`` before its validity mask ever reads it; no consumer may
    assume they are meaningful (or zero).
    With an int8 cache, attention reads the quantized representation
    (see ``_gqa_forward(quantize_kv=...)``) so the fused path stays a
    token-exact oracle for chunked paged prefill.
    Returns (y, cache) with the same cache pytree as gqa_cache_init."""
    int8 = getattr(cfg, "kv_cache_dtype", "") == "int8"
    y, k, v = _gqa_forward(params, x, cfg, positions, quantize_kv=int8)
    if int8:
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        cache = {"k": _pad_seq(kq, max_seq), "v": _pad_seq(vq, max_seq),
                 "k_scale": _pad_seq(ks, max_seq),
                 "v_scale": _pad_seq(vs, max_seq)}
    else:
        cache = {"k": _pad_seq(k, max_seq), "v": _pad_seq(v, max_seq)}
    return y, cache


def gqa_cache_init(cfg, batch: int, max_seq: int, dtype):
    """KV cache holding the *true* kv heads (head padding happens at use).

    With ``cfg.kv_cache_dtype == 'int8'`` (beyond-paper, §Perf): values are
    stored quantized with one scale per (batch, position, head) row —
    halving the decode-dominating cache bytes; dequantization is fused into
    the attention reads."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if getattr(cfg, "kv_cache_dtype", "") == "int8":
        z8 = jnp.zeros((batch, max_seq, hkv, dh), jnp.int8)
        zs = jnp.zeros((batch, max_seq, hkv), jnp.float32)
        return {"k": z8, "v": z8, "k_scale": zs, "v_scale": zs}
    return {"k": jnp.zeros((batch, max_seq, hkv, dh), dtype),
            "v": jnp.zeros((batch, max_seq, hkv, dh), dtype)}


def _quant_rows(x):
    """Per-(..., head)-row symmetric int8 quantization over head_dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _cache_write(cache, new, pos, mode: str = None):
    """Write one position into a (B, S, ...) cache.

    ``pos`` may be a scalar (all rows at the same position — the static
    batch) or a (B,) vector of per-row positions (continuous batching:
    every slot decodes at its own depth).

    ``dynamic_update_slice`` at a traced index on the sequence axis defeats
    GSPMD when the cache is sequence-sharded (SP): it all-gathers the whole
    cache (measured: 34 GB/step collectives on yi-6b decode_32k).

    modes (cfg.cache_write):
      masked — one-hot elementwise write: partitions on every axis, costs
               one full cache read+write per step (the safe default).
      owner  — shard_map row-owner write (§Perf hillclimb A rung 3): only
               the shard owning position ``pos`` runs a local
               dynamic_update_slice; other shards pass through untouched.
               Scalar ``pos`` only; vector positions fall back to masked.
    """
    mode = mode or "masked"
    pos = jnp.asarray(pos, jnp.int32)
    s = cache.shape[1]
    if pos.ndim == 1:  # per-slot positions: (B, S) one-hot masked write
        hot = jnp.arange(s)[None, :] == pos[:, None]
        hot = hot.reshape(hot.shape + (1,) * (cache.ndim - 2))
        return jnp.where(hot, new.astype(cache.dtype), cache)
    if mode == "owner":
        owner = _owner_write(cache, new, pos)
        if owner is not None:
            return owner
    hot = (jnp.arange(s) == pos)
    shape = [1, s] + [1] * (cache.ndim - 2)
    hot = hot.reshape(shape)
    return jnp.where(hot, new.astype(cache.dtype), cache)



def _owner_write(cache, new, pos):
    """shard_map write into the sequence-sharded cache; returns None when
    no rules/sharding apply (caller falls back to the masked write)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.context import get_rules
    rules = get_rules()
    if rules is None:
        return None
    axes = rules.resolve("kvseq", cache.shape[1])
    if axes is None:
        return None
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    b_axes = rules.resolve("batch", cache.shape[0])
    mesh = rules.mesh
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    shard_len = cache.shape[1] // n_shards
    cache_spec = P(b_axes, axes, *([None] * (cache.ndim - 2)))
    new_spec = P(b_axes, None, *([None] * (cache.ndim - 2)))

    def local(c, n, p):
        # linearized shard index over the (possibly multi-axis) seq axes
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        start = idx * shard_len
        lp = p - start
        in_range = jnp.logical_and(lp >= 0, lp < shard_len)

        def write(c):
            lpc = jnp.clip(lp, 0, shard_len - 1)
            starts = (0, lpc) + (0,) * (c.ndim - 2)
            return lax.dynamic_update_slice(c, n.astype(c.dtype), starts)

        return lax.cond(in_range, write, lambda c: c, c)

    from repro.sharding.context import shard_map
    return shard_map(
        local, mesh=mesh, in_specs=(cache_spec, new_spec, P()),
        out_specs=cache_spec, check_vma=False,
    )(cache, new, pos if hasattr(pos, "dtype") else jnp.int32(pos))


def gqa_cache_specs(cfg=None):
    specs = {"k": ("batch", "kvseq", "kv", None),
             "v": ("batch", "kvseq", "kv", None)}
    if cfg is not None and getattr(cfg, "kv_cache_dtype", "") == "int8":
        specs["k_scale"] = ("batch", "kvseq", "kv")
        specs["v_scale"] = ("batch", "kvseq", "kv")
    return specs


def _kv_update(cache, k, v, cfg, pos, pos_b, pages):
    """Write the new K/V row(s) and return ``(new_cache, k_view, v_view)``
    where the views are the readable (dequantized) full-length caches.

    ``pages=None`` — contiguous layout: masked/owner write into the
    (B, max_seq, ...) cache, the view IS the cache.
    ``pages`` given — paged layout: scatter each slot's row into its page
    chain (:func:`repro.runtime.kvcache.paged_write_rows`) and gather the
    (B, view_len, ...) slot-logical read view.  Inactive slots' page
    tables are all null, so their stale writes land in the null page.
    """
    if pages is None:
        write = lambda c, n: _cache_write(c, n, pos, cfg.cache_write)
        view = lambda c: c
    else:
        write = lambda c, n: paged_write_rows(c, n[:, 0], pages, pos_b)
        view = lambda c: paged_view(c, pages)
    new_cache = {}
    if "k_scale" in cache:  # int8-quantized cache (beyond-paper)
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        new_cache["k"] = write(cache["k"], kq)
        new_cache["v"] = write(cache["v"], vq)
        new_cache["k_scale"] = write(cache["k_scale"], ks)
        new_cache["v_scale"] = write(cache["v_scale"], vs)
        k_view = (view(new_cache["k"]).astype(k.dtype)
                  * view(new_cache["k_scale"])[..., None].astype(k.dtype))
        v_view = (view(new_cache["v"]).astype(k.dtype)
                  * view(new_cache["v_scale"])[..., None].astype(k.dtype))
    else:
        new_cache["k"] = write(cache["k"], k)
        new_cache["v"] = write(cache["v"], v)
        k_view = view(new_cache["k"])
        v_view = view(new_cache["v"])
    return new_cache, k_view, v_view


def _gqa_cache_attn(params, x, q, k_view, v_view, valid, cfg):
    """Attention of (B, S_q, H, Dh) queries over a full-length cache view
    with a broadcastable validity mask ``valid`` (B|1, S_q|1, V) — the
    shared tail of the decode step and the chunked-prefill step."""
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hp = cfg.padded_heads
    q = _pad_heads(q, hp)
    kf = _pad_heads(_repeat_kv(k_view, h // hkv), hp)
    vf = _pad_heads(_repeat_kv(v_view, h // hkv), hp)
    scale = 1.0 / np.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    out = _mask_dummy_heads(out, cfg)
    return _o_proj(params["o"], out.reshape(*x.shape[:-1], hp * dh),
                   cfg.proj_sparsity)


def gqa_decode(params, x, cfg, cache, pos, pages=None):
    """One-token decode step. x: (B, 1, D); pos: scalar current position,
    or a (B,) vector of per-row positions (continuous batching — each slot
    sits at its own depth in the cache).

    The new K/V row is scattered into the cache at ``pos``; attention reads
    the full cache with a validity mask (positions > pos are masked).  With
    the cache sequence axis sharded ("kvseq" -> model/SP), GSPMD turns the
    softmax reductions into cross-shard collectives.

    With ``pages`` (a (B, n_blocks) int32 page table) the cache leaves are
    the PAGED pool ``(n_pages, page_size, ...)``: the row write scatters
    into each slot's own page chain and attention runs over the gathered
    per-slot view — same math, same mask, decoupled memory.
    """
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = cfg.proj_sparsity
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    positions = pos_b[:, None]
    q = _split_heads(_proj_apply(params["q"], x, sp), h, dh)
    k = _split_heads(_proj_apply(params["k"], x, sp), hkv, dh)
    v = _split_heads(_proj_apply(params["v"], x, sp), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache, k_view, v_view = _kv_update(cache, k, v, cfg, pos, pos_b,
                                           pages)
    valid = (jnp.arange(k_view.shape[1])[None, None, :]
             <= pos_b[:, None, None])
    y = _gqa_cache_attn(params, x, q, k_view, v_view, valid, cfg)
    return y, new_cache


def gqa_chunk_prefill(params, x, cfg, cache, pages, pos_start, chunk_len):
    """Chunked prefill over the PAGED cache: forward C prompt tokens of
    ONE slot at absolute positions [pos_start, pos_start + C), scattering
    their K/V rows into the slot's page chain and attending causally to
    the gathered history (earlier chunks are already in the pool).  Rows
    past ``chunk_len`` are bucket padding: their K/V is redirected to the
    null page and their outputs are garbage the caller ignores.

    x: (1, C, D); pages: (1, n_blocks) int32; pos_start/chunk_len:
    scalars (traced — one compile per chunk bucket, not per length).
    Returns (y (1, C, D), new_cache with pool-shaped leaves)."""
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = cfg.proj_sparsity
    b, c, _ = x.shape
    pos0 = jnp.asarray(pos_start, jnp.int32)
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = jnp.broadcast_to(pos0 + offs, (b, c))
    q = _split_heads(_proj_apply(params["q"], x, sp), h, dh)
    k = _split_heads(_proj_apply(params["k"], x, sp), hkv, dh)
    v = _split_heads(_proj_apply(params["v"], x, sp), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    write = lambda pool, rows: paged_write_chunk(pool, rows, pages[0],
                                                 pos0, chunk_len)
    new_cache = {}
    if "k_scale" in cache:  # int8-quantized cache (beyond-paper)
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        new_cache["k"] = write(cache["k"], kq[0])
        new_cache["v"] = write(cache["v"], vq[0])
        new_cache["k_scale"] = write(cache["k_scale"], ks[0])
        new_cache["v_scale"] = write(cache["v_scale"], vs[0])
        k_view = (paged_view(new_cache["k"], pages).astype(x.dtype)
                  * paged_view(new_cache["k_scale"],
                               pages)[..., None].astype(x.dtype))
        v_view = (paged_view(new_cache["v"], pages).astype(x.dtype)
                  * paged_view(new_cache["v_scale"],
                               pages)[..., None].astype(x.dtype))
    else:
        new_cache["k"] = write(cache["k"], k[0])
        new_cache["v"] = write(cache["v"], v[0])
        k_view = paged_view(new_cache["k"], pages)
        v_view = paged_view(new_cache["v"], pages)
    # causal in slot-logical coordinates: chunk row j sees cols <= pos0+j
    valid = (jnp.arange(k_view.shape[1])[None, None, :]
             <= (pos0 + offs)[None, :, None])
    y = _gqa_cache_attn(params, x, q, k_view, v_view, valid, cfg)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    params = {
        "q": normal_init(ks[0], (d, h * (dh + dr)), 0.02),
        "dkv": normal_init(ks[1], (d, r), 0.02),
        "kpe": normal_init(ks[2], (d, dr), 0.02),
        "uk": normal_init(ks[3], (r, h * dh), 0.02),
        "uv": normal_init(ks[4], (r, h * dh), 0.02),
        "o": normal_init(ks[5], (h * dh, d), 0.02),
    }
    specs = {"q": (None, "heads"), "dkv": (None, None), "kpe": (None, None),
             "uk": (None, "heads"), "uv": (None, "heads"),
             "o": ("heads", None)}
    return params, specs


def _mla_qkv(params, x, cfg, positions):
    h, dh, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    ct = x.dtype
    q = (x @ params["q"].astype(ct)).reshape(*x.shape[:-1], h, dh + dr)
    q_nope, q_pe = q[..., :dh], q[..., dh:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    c_kv = x @ params["dkv"].astype(ct)                      # (B, S, r)
    k_pe = apply_rope(x @ params["kpe"].astype(ct), positions,
                      cfg.rope_theta)                        # (B, S, dr)
    return q_nope, q_pe, c_kv, k_pe


def _mla_expand(params, c_kv, cfg, ct):
    h, dh = cfg.n_heads, cfg.head_dim
    k_nope = (c_kv @ params["uk"].astype(ct)).reshape(*c_kv.shape[:-1], h, dh)
    v = (c_kv @ params["uv"].astype(ct)).reshape(*c_kv.shape[:-1], h, dh)
    return k_nope, v


def _mla_forward(params, x, cfg, positions):
    """Full causal MLA forward. Returns (y, c_kv, k_pe) — the latent rows
    the decode cache stores (fused-prefill bulk write)."""
    h, dh, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    k_nope, v = _mla_expand(params, c_kv, cfg, x.dtype)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_pe[..., None, :],
                                          (*k_pe.shape[:-1], h, dr))], axis=-1)
    scale = 1.0 / np.sqrt(dh + dr)
    if x.shape[1] > cfg.flash_block:
        out = _flash_attn(q, k, v, scale, cfg.flash_block,
                          unroll=cfg.unroll_inner)
    else:
        out = _causal_attn(q, k, v, scale)
    y = out.reshape(*x.shape[:-1], h * dh) @ params["o"].astype(x.dtype)
    return y, c_kv, k_pe


def mla_apply(params, x, cfg, positions):
    return _mla_forward(params, x, cfg, positions)[0]


def mla_cache_init(cfg, batch: int, max_seq: int, dtype):
    """MLA caches the compressed latent + rope key only: (r + dr) per token
    — the paper-adjacent memory win that makes MLA decode cheap."""
    return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype)}


def mla_cache_specs():
    return {"ckv": ("batch", "kvseq", None), "kpe": ("batch", "kvseq", None)}


def mla_prefill(params, x, cfg, positions, max_seq: int):
    """Fused full-sequence MLA prefill: forward + bulk latent-cache write
    (same contract as :func:`gqa_prefill`)."""
    y, c_kv, k_pe = _mla_forward(params, x, cfg, positions)
    return y, {"ckv": _pad_seq(c_kv, max_seq), "kpe": _pad_seq(k_pe, max_seq)}


def _mla_cache_attn(params, x, q_nope, q_pe, ckv_view, kpe_view, valid, cfg):
    """MLA attention over full-length latent-cache views with a
    broadcastable validity mask ``valid`` (B|1, S_q|1, V) — the shared
    tail of the decode step and the chunked-prefill step."""
    h, dh, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    k_nope, v = _mla_expand(params, ckv_view, cfg, x.dtype)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(kpe_view[..., None, :],
                                          (*kpe_view.shape[:-1], h, dr))],
                        axis=-1)
    scale = 1.0 / np.sqrt(dh + dr)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(*x.shape[:-1], h * dh) @ params["o"].astype(x.dtype)


def mla_decode(params, x, cfg, cache, pos, pages=None):
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    positions = pos_b[:, None]
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    if pages is None:
        new_cache = {
            "ckv": _cache_write(cache["ckv"], c_kv, pos, cfg.cache_write),
            "kpe": _cache_write(cache["kpe"], k_pe, pos, cfg.cache_write),
        }
        ckv_view, kpe_view = new_cache["ckv"], new_cache["kpe"]
    else:
        new_cache = {
            "ckv": paged_write_rows(cache["ckv"], c_kv[:, 0], pages, pos_b),
            "kpe": paged_write_rows(cache["kpe"], k_pe[:, 0], pages, pos_b),
        }
        ckv_view = paged_view(new_cache["ckv"], pages)
        kpe_view = paged_view(new_cache["kpe"], pages)
    valid = (jnp.arange(ckv_view.shape[1])[None, None, :]
             <= pos_b[:, None, None])
    y = _mla_cache_attn(params, x, q_nope, q_pe, ckv_view, kpe_view, valid,
                        cfg)
    return y, new_cache


def mla_chunk_prefill(params, x, cfg, cache, pages, pos_start, chunk_len):
    """Chunked MLA prefill over the PAGED latent cache — the MLA
    counterpart of :func:`gqa_chunk_prefill` (same contract: x (1, C, D),
    pages (1, n_blocks), traced pos_start/chunk_len, padded rows sink to
    the null page)."""
    b, c, _ = x.shape
    pos0 = jnp.asarray(pos_start, jnp.int32)
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = jnp.broadcast_to(pos0 + offs, (b, c))
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    new_cache = {
        "ckv": paged_write_chunk(cache["ckv"], c_kv[0], pages[0], pos0,
                                 chunk_len),
        "kpe": paged_write_chunk(cache["kpe"], k_pe[0], pages[0], pos0,
                                 chunk_len),
    }
    ckv_view = paged_view(new_cache["ckv"], pages)
    kpe_view = paged_view(new_cache["kpe"], pages)
    valid = (jnp.arange(ckv_view.shape[1])[None, None, :]
             <= (pos0 + offs)[None, :, None])
    y = _mla_cache_attn(params, x, q_nope, q_pe, ckv_view, kpe_view, valid,
                        cfg)
    return y, new_cache
