"""Mixture-of-Experts with sort-based capacity dispatch (dropless up to the
capacity factor) and expert parallelism over the "experts" logical axis.

Design notes (DESIGN.md §7): MoE routing is itself *coarse-grained
activation sparsity* — the router is a learned top-k over expert 'units',
directly analogous to the paper's k-WTA over neurons.  Complementary
sparsity composes inside each expert's FFN (fine-grained weight sparsity),
giving the 'two sparsities' at two granularities.

Dispatch is static-shaped and TPU-friendly:
  1. top-k expert choice per token (router softmax),
  2. stable argsort of the (T·k) assignments by expert id,
  3. rank-within-expert via running offsets; tokens beyond capacity C drop,
  4. scatter into an (E, C, d) buffer, batched expert FFN (one einsum per
     projection, E sharded over the model axis = EP),
  5. weighted combine back via the inverse gather.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.api import SparsityConfig
from repro.sharding.context import constrain
from .common import normal_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             n_shared: int, act: str, cfg_sp: SparsityConfig):
    """Experts hold stacked SwiGLU weights (E, d, ff)/(E, ff, d).

    When cfg_sp.weight_sparse, expert weights are stored packed:
    (E, G, P, N) with a single route table shared across experts (a codesign
    choice — routes are arbitrary, sharing keeps the HLO small; per-expert
    connectivity diversity is preserved by the weights themselves).
    """
    ks = jax.random.split(key, 5)
    params, specs = {}, {}
    params["router"] = normal_init(ks[0], (d_model, n_experts), 0.02)
    specs["router"] = (None, "experts")

    def mk_expert(key, d_in, d_out, seed):
        if cfg_sp.weight_sparse and d_in % cfg_sp.n == 0 and d_out % cfg_sp.n == 0:
            from repro.core.masks import CSLayout, make_routes
            lay = CSLayout(d_in, d_out, cfg_sp.n, cfg_sp.perm_kind)
            g = lay.groups
            r = g if cfg_sp.route_share == 0 else min(cfg_sp.route_share, g)
            while g % r:
                r -= 1
            route = make_routes(
                CSLayout(d_in, cfg_sp.n * (g // r), cfg_sp.n,
                         cfg_sp.perm_kind), seed)
            scale = np.sqrt(cfg_sp.n / d_in)
            w = jax.random.uniform(key, (n_experts, g, lay.partitions, cfg_sp.n),
                                   jnp.float32, -scale, scale)
            return ({"packed": w, "route": jnp.asarray(route)},
                    {"packed": ("experts", "mlp", None, None),
                     "route": ("mlp", None, None)})
        scale = 1.0 / np.sqrt(d_in)
        w = jax.random.uniform(key, (n_experts, d_in, d_out), jnp.float32,
                               -scale, scale)
        return {"w": w}, {"w": ("experts", None, "mlp" if d_out == d_ff else None)}

    params["up"], specs["up"] = mk_expert(ks[1], d_model, d_ff, 31)
    if act == "silu":
        params["gate"], specs["gate"] = mk_expert(ks[2], d_model, d_ff, 32)
    params["down"], specs["down"] = mk_expert(ks[3], d_ff, d_model, 33)
    if n_shared:
        from .ffn import ffn_init
        params["shared"], specs["shared"] = ffn_init(
            ks[4], d_model, n_shared * d_ff, cfg_sp, act)
    return params, specs


def _expert_matmul(p, x, sp: SparsityConfig):
    """Batched expert projection: x (..., E, C, d_in) -> (..., E, C,
    d_out)."""
    if "packed" in p:
        from repro.core import functional as F
        pk = p["packed"].astype(x.dtype)
        fn = lambda xe, pe: F.cs_matmul(xe, pe, p["route"])  # noqa: E731
        over_e = jax.vmap(fn, in_axes=(0, 0))
        if x.ndim == 4:  # leading group axis
            return jax.vmap(over_e, in_axes=(0, None))(x, pk)
        return over_e(x, pk)
    return jnp.einsum("...ecd,edf->...ecf", x, p["w"].astype(x.dtype))


def _dispatch_group(xg, top_p, top_e, e: int, k: int, cap: int):
    """Sort-based dispatch for ONE token group.

    xg: (Tg, d); top_p/top_e: (Tg, k). Returns (buf (E, C, d),
    e_sorted, rank_c, keep, w_sorted, tok_sorted) for the combine."""
    tg, d = xg.shape
    e_flat = top_e.reshape(-1)                               # (Tg*k,)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = order // k
    counts = jnp.bincount(e_sorted, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(tg * k) - starts[e_sorted]
    keep = rank < cap
    rank_c = jnp.where(keep, rank, cap - 1).astype(jnp.int32)
    buf = jnp.zeros((e, cap, d), xg.dtype)
    src = jnp.where(keep[:, None], xg[tok_sorted], 0).astype(xg.dtype)
    buf = buf.at[e_sorted, rank_c].add(src)                  # (E, C, d)
    w_sorted = top_p.reshape(-1)[order]
    return buf, e_sorted, rank_c, keep, w_sorted, tok_sorted


def _combine_group(out, e_sorted, rank_c, keep, w_sorted, tok_sorted,
                   tg: int):
    gathered = out[e_sorted, rank_c]                         # (Tg*k, d)
    contrib = gathered * (w_sorted * keep)[:, None].astype(out.dtype)
    return jnp.zeros((tg, out.shape[-1]), out.dtype).at[tok_sorted].add(
        contrib)


def moe_apply(params, x, cfg, cfg_sp: SparsityConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (y, aux_loss).

    Dispatch runs **per token group** (vmapped): the group axis preserves
    the batch sharding, so the (groups, E, C, d) buffer shards over DP x EP
    and the scatter/sort never crosses data shards.  A single global
    dispatch (no group axis) has no batch dim on the buffer — GSPMD
    replicates the scatter and the 1M-token qwen3 dispatch buffer exploded
    to ~420 GB/device (measured; see EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    # group count: one group per batch row keeps sharding natural
    groups = b
    tg = t // groups
    xg = x.reshape(groups, tg, d)
    logits = (xg @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, Tg, E)
    top_p, top_e = lax.top_k(probs, k)                       # (G, Tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style, global) ----
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch (vmapped) ----
    cap = int(np.ceil(tg * k / e * cfg.capacity_factor))
    buf, e_sorted, rank_c, keep, w_sorted, tok_sorted = jax.vmap(
        lambda xg_, p_, e_: _dispatch_group(xg_, p_, e_, e, k, cap)
    )(xg, top_p, top_e)
    buf = constrain(buf, "batch", "experts", None, None)     # (G, E, C, d)

    # ---- batched expert FFN (experts sharded over model = EP) ----
    up = _expert_matmul(params["up"], buf, cfg_sp)
    if "gate" in params:
        h = jax.nn.silu(_expert_matmul(params["gate"], buf, cfg_sp)) * up
    else:
        h = jax.nn.gelu(up)
    if cfg_sp.activation_sparse:
        from repro.core.layers import apply_kwta
        h = apply_kwta(h, cfg_sp)
    out = _expert_matmul(params["down"], h, cfg_sp)          # (G, E, C, d)
    out = constrain(out, "batch", "experts", None, None)

    # ---- combine (vmapped inverse gather) ----
    y = jax.vmap(lambda o, es, rc, kp, ws, ts: _combine_group(
        o, es, rc, kp, ws, ts, tg))(out, e_sorted, rank_c, keep, w_sorted,
                                    tok_sorted)
    y = y.reshape(b, s, d)

    if "shared" in params:
        from .ffn import ffn_apply
        y = y + ffn_apply(params["shared"], x, cfg_sp, "silu")
    return y, aux
