"""State-space / recurrent blocks: a shared chunked-SSD scan used by both
Mamba2 (zamba2) and mLSTM (xLSTM), plus the strictly-sequential sLSTM.

Chunked SSD (the Mamba-2 'state-space duality' algorithm, also the
chunkwise-parallel mLSTM form): with per-step scalar decay a_t and update
S_t = a_t·S_{t-1} + k_t v_t^T, y_t = q_t·S_t, split T into chunks of L:

  intra-chunk: (Q K^T ⊙ D) V with D[i,j] = exp(cum_i - cum_j)·[j <= i]
  inter-chunk: (Q ⊙ exp(cum)) S_prev
  state carry: S_next = exp(cum_L) S_prev + Σ_j exp(cum_L - cum_j) k_j v_j^T

All contractions are MXU-shaped einsums; the only sequential dependency is
the O(T/L) chunk scan.  Decode is the O(1) recurrent update — this is what
makes the SSM/hybrid architectures run the `long_500k` cell that quadratic
attention cannot (DESIGN.md §7).

The mLSTM normalizer n_t = f n_{t-1} + i k_t is folded in by augmenting V
with a ones column (y = (q·S)/max(|q·n|, 1)).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sharding.context import constrain
from .common import normal_init, rmsnorm_apply, rmsnorm_init


# ---------------------------------------------------------------------------
# Chunked SSD scan
# ---------------------------------------------------------------------------

def ssd_scan(q, k, v, log_a, chunk: int, unroll: bool = False):
    """q,k: (B, T, H, Dk); v: (B, T, H, Dv); log_a: (B, T, H) (<= 0).

    Returns y: (B, T, H, Dv), final state (B, H, Dk, Dv).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, t)
    if t % L:
        raise ValueError(f"T={t} not divisible by chunk={L}")
    nc = t // L

    qc = q.reshape(b, nc, L, h, dk).swapaxes(0, 1)
    kc = k.reshape(b, nc, L, h, dk).swapaxes(0, 1)
    vc = v.reshape(b, nc, L, h, dv).swapaxes(0, 1)
    lac = log_a.reshape(b, nc, L, h).swapaxes(0, 1)
    causal = np.tril(np.ones((L, L), bool))

    @jax.checkpoint  # recompute intra-chunk scores in the backward pass
    def body(S, xs):
        qb, kb, vb, lab = xs                     # (B, L, H, *)
        cum = jnp.cumsum(lab, axis=1)            # (B, L, H) inclusive
        # intra-chunk
        scores = jnp.einsum("bihd,bjhd->bhij", qb.astype(jnp.float32),
                            kb.astype(jnp.float32))
        decay = cum[:, :, None] - cum[:, None, :]   # (B, L_i, L_j, H)
        decay = jnp.transpose(decay, (0, 3, 1, 2))  # (B, H, L, L)
        dmask = jnp.where(causal[None, None], jnp.exp(decay), 0.0)
        y_intra = jnp.einsum("bhij,bjhd->bihd", scores * dmask,
                             vb.astype(jnp.float32))
        # inter-chunk
        qdec = qb.astype(jnp.float32) * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bihd,bhde->bihe", qdec, S)
        # state update
        tot = cum[:, -1:, :]                       # (B, 1, H)
        kdec = kb.astype(jnp.float32) * jnp.exp(tot - cum)[..., None]
        S_new = (jnp.exp(tot[:, 0, :, None, None]) * S
                 + jnp.einsum("bjhd,bjhe->bhde", kdec, vb.astype(jnp.float32)))
        return S_new, (y_intra + y_inter).astype(v.dtype)

    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    S_fin, yc = lax.scan(body, S0, (qc, kc, vc, lac),
                         unroll=nc if unroll else 1)
    y = yc.swapaxes(0, 1).reshape(b, t, h, dv)
    return y, S_fin


def ssd_step(S, q, k, v, log_a):
    """O(1) recurrent decode step. q,k: (B,H,Dk); v: (B,H,Dv); log_a: (B,H).
    Returns (y (B,H,Dv), S_new)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    S_new = a * S + jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                               v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), S_new)
    return y.astype(v.dtype), S_new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    nh = d_inner // hd
    ds = cfg.ssm_state
    ks = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * ds
    params = {
        # projects to [x (d_inner), B (ds), C (ds), dt (nh), z (d_inner)]
        "in_proj": normal_init(ks[0], (d, d_inner + 2 * ds + nh + d_inner),
                               0.02),
        "conv_w": normal_init(ks[1], (cfg.conv_kernel, conv_dim), 0.1),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": normal_init(ks[2], (d_inner, d), 0.02),
        "norm": rmsnorm_init(d_inner)[0],
    }
    specs = {
        "in_proj": (None, "mlp"), "conv_w": (None, "mlp"),
        "conv_b": ("mlp",), "A_log": ("mlp",), "dt_bias": ("mlp",),
        "D": ("mlp",), "out_proj": ("mlp", None), "norm": {"scale": (None,)},
    }
    return params, specs


def _mamba2_project(params, x, cfg):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    ds = cfg.ssm_state
    nh = d_inner // cfg.ssm_head_dim
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    xs = jnp.split(zxbcdt, [d_inner, d_inner + ds, d_inner + 2 * ds,
                            d_inner + 2 * ds + nh], axis=-1)
    xin, B, C, dt, z = xs
    return xin, B, C, dt, z


def _causal_conv(seq, w, b, cache=None):
    """Depthwise causal conv over time. seq: (B, T, C); w: (K, C).

    With ``cache`` ((B, K-1, C) trailing context) performs the streaming
    update and returns (out, new_cache)."""
    kk = w.shape[0]
    if cache is None:
        pad = jnp.zeros((seq.shape[0], kk - 1, seq.shape[2]), seq.dtype)
    else:
        pad = cache
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i].astype(seq.dtype)
              for i in range(kk))
    out = out + b.astype(seq.dtype)
    new_cache = full[:, -(kk - 1):] if kk > 1 else pad
    return jax.nn.silu(out), new_cache


def mamba2_apply(params, x, cfg):
    """Training/prefill forward. x: (B, T, D)."""
    b, t, d = x.shape
    hd = cfg.ssm_head_dim
    d_inner = cfg.ssm_expand * d
    nh = d_inner // hd
    ds = cfg.ssm_state
    xin, B, C, dt, z = _mamba2_project(params, x, cfg)
    xbc, _ = _causal_conv(jnp.concatenate([xin, B, C], axis=-1),
                          params["conv_w"], params["conv_b"])
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])               # (B,T,nh)
    log_a = -jnp.exp(params["A_log"])[None, None] * dt      # (B,T,nh) <= 0
    xh = xin.reshape(b, t, nh, hd)
    # B/C are shared across heads (Mamba2 'multi-value' pattern)
    k = jnp.broadcast_to(B[:, :, None, :], (b, t, nh, ds))
    q = jnp.broadcast_to(C[:, :, None, :], (b, t, nh, ds))
    kdt = k * dt[..., None].astype(k.dtype)
    y, _ = ssd_scan(q, kdt, xh, log_a, cfg.ssm_chunk,
                    unroll=cfg.unroll_inner)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, t, d_inner) * jax.nn.silu(z)
    y = rmsnorm_apply(params["norm"], y)
    return y @ params["out_proj"].astype(x.dtype)


def mamba2_cache_init(cfg, batch: int, dtype):
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return {"S": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype)}


def mamba2_cache_specs():
    return {"S": ("batch", "mlp", None, None),
            "conv": ("batch", None, "mlp")}


def mamba2_decode(params, x, cfg, cache, pos):
    """One-token step: O(1) state update (the long_500k path)."""
    del pos
    b, t, d = x.shape
    hd = cfg.ssm_head_dim
    d_inner = cfg.ssm_expand * d
    nh = d_inner // hd
    ds = cfg.ssm_state
    xin, B, C, dt, z = _mamba2_project(params, x, cfg)
    xbc, conv_new = _causal_conv(jnp.concatenate([xin, B, C], axis=-1),
                                 params["conv_w"], params["conv_b"],
                                 cache["conv"])
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    log_a = (-jnp.exp(params["A_log"])[None, None] * dt)[:, 0]   # (B, nh)
    xh = xin.reshape(b, nh, hd)
    k = jnp.broadcast_to(B[:, 0, None, :], (b, nh, ds))
    q = jnp.broadcast_to(C[:, 0, None, :], (b, nh, ds))
    kdt = k * dt[:, 0, :, None].astype(k.dtype)
    y, S_new = ssd_step(cache["S"], q, kdt, xh, log_a)
    y = y + params["D"][None, :, None].astype(y.dtype) * xh
    y = (y.reshape(b, 1, d_inner) * jax.nn.silu(z))
    y = rmsnorm_apply(params["norm"], y)
    return y @ params["out_proj"].astype(x.dtype), \
        {"S": S_new, "conv": conv_new}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    params = {
        "qkv": normal_init(ks[0], (d, 3 * d), 0.02),
        "gates": normal_init(ks[1], (d, 2 * h), 0.02),   # i, f per head
        "gate_b": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "out_proj": normal_init(ks[2], (d, d), 0.02),
        "norm": rmsnorm_init(d)[0],
        "skip": jnp.ones((h,), jnp.float32),
    }
    specs = {"qkv": (None, "heads"), "gates": (None, "heads"),
             "gate_b": ("heads",), "out_proj": ("heads", None),
             "norm": {"scale": (None,)}, "skip": ("heads",)}
    return params, specs


def _mlstm_qkvg(params, x, cfg):
    b, t, d = x.shape
    h = cfg.n_heads
    dh = d // h
    qkv = x @ params["qkv"].astype(x.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, dh)
    k = k.reshape(b, t, h, dh) / np.sqrt(dh)
    v = v.reshape(b, t, h, dh)
    gates = (x @ params["gates"].astype(x.dtype)).astype(jnp.float32)
    gates = gates + params["gate_b"]
    ig, fg = jnp.split(gates, 2, axis=-1)                  # (B, T, H)
    log_f = jax.nn.log_sigmoid(fg)
    i = jnp.exp(jax.nn.log_sigmoid(ig))  # sigmoid input gate (stabilized)
    return q, k, v, i, log_f


def _mlstm_finalize(params, y_aug, xh, cfg):
    """Split the augmented value (v, 1) -> normalize, skip, project."""
    b, t = y_aug.shape[:2]
    h = cfg.n_heads
    y, nrm = y_aug[..., :-1], y_aug[..., -1:]
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = y + params["skip"][None, None, :, None].astype(y.dtype) * xh
    d = cfg.d_model
    y = rmsnorm_apply(params["norm"], y.reshape(b, t, d))
    return y @ params["out_proj"].astype(y.dtype)


def mlstm_apply(params, x, cfg):
    b, t, d = x.shape
    h = cfg.n_heads
    dh = d // h
    q, k, v, i, log_f = _mlstm_qkvg(params, x, cfg)
    v_aug = jnp.concatenate([v, jnp.ones((b, t, h, 1), v.dtype)], axis=-1)
    ki = k * i[..., None].astype(k.dtype)
    y_aug, _ = ssd_scan(q, ki, v_aug, log_f, cfg.ssm_chunk,
                        unroll=cfg.unroll_inner)
    return _mlstm_finalize(params, y_aug, q, cfg)


def mlstm_cache_init(cfg, batch: int, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {"S": jnp.zeros((batch, h, dh, dh + 1), jnp.float32)}


def mlstm_cache_specs():
    return {"S": ("batch", "heads", None, None)}


def mlstm_decode(params, x, cfg, cache, pos):
    del pos
    b, t, d = x.shape
    h = cfg.n_heads
    q, k, v, i, log_f = _mlstm_qkvg(params, x, cfg)
    v_aug = jnp.concatenate([v, jnp.ones((b, t, h, 1), v.dtype)], axis=-1)
    ki = (k * i[..., None].astype(k.dtype))[:, 0]
    y_aug, S_new = ssd_step(cache["S"], q[:, 0], ki, v_aug[:, 0], log_f[:, 0])
    y = _mlstm_finalize(params, y_aug[:, None], q, cfg)
    return y, {"S": S_new}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): strictly sequential scalar-memory recurrence
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    params = {
        "wx": normal_init(ks[0], (d, 4 * d), 0.02),         # z i f o
        "r": normal_init(ks[1], (h, dh, 4 * dh), 1.0 / np.sqrt(dh)),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": normal_init(ks[2], (d, d), 0.02),
        "norm": rmsnorm_init(d)[0],
    }
    specs = {"wx": (None, "heads"), "r": ("heads", None, None),
             "b": ("heads",), "out_proj": (None, None),
             "norm": {"scale": (None,)}}
    return params, specs


def _slstm_cell(params, cfg, carry, zx):
    """One recurrent step. carry: (h, c, n); zx: (B, 4D) pre-activations."""
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    h_prev, c_prev, n_prev = carry
    hr = jnp.einsum("bhd,hde->bhe", h_prev.reshape(-1, nh, dh),
                    params["r"].astype(h_prev.dtype)).reshape(-1, 4 * d)
    pre = (zx + hr).astype(jnp.float32) + params["b"]
    z, ig, fg, og = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.minimum(ig, 0.0))        # stabilized exponential gate
    f = jax.nn.sigmoid(fg)
    o = jax.nn.sigmoid(og)
    c = f * c_prev + i * z
    n = f * n_prev + i
    h_new = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return (h_new.astype(h_prev.dtype), c, n)


def slstm_apply(params, x, cfg):
    b, t, d = x.shape
    zx = x @ params["wx"].astype(x.dtype)                   # (B, T, 4D)

    def step(carry, zx_t):
        carry = _slstm_cell(params, cfg, carry, zx_t)
        return carry, carry[0]

    init = (jnp.zeros((b, d), x.dtype), jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32))
    _, hs = lax.scan(step, init, zx.swapaxes(0, 1))
    y = rmsnorm_apply(params["norm"], hs.swapaxes(0, 1))
    return y @ params["out_proj"].astype(x.dtype)


def slstm_cache_init(cfg, batch: int, dtype):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32)}


def slstm_cache_specs():
    return {"h": ("batch", None), "c": ("batch", None), "n": ("batch", None)}


def slstm_decode(params, x, cfg, cache, pos):
    del pos
    zx = (x @ params["wx"].astype(x.dtype))[:, 0]
    carry = (cache["h"], cache["c"], cache["n"])
    h_new, c, n = _slstm_cell(params, cfg, carry, zx)
    y = rmsnorm_apply(params["norm"], h_new[:, None])
    y = y @ params["out_proj"].astype(x.dtype)
    return y, {"h": h_new, "c": c, "n": n}
