"""Shared model components: norms, RoPE, embeddings, init and param-spec
conventions.

Params are nested dicts of arrays; every ``*_init`` returns ``(params,
specs)`` where ``specs`` mirrors the params tree with tuples of *logical*
axis names (resolved to mesh axes by repro.sharding).  Logical axes used:

  "embed"   — d_model            (replicated)
  "heads"   — attention heads    -> model axis
  "kv"      — kv heads           -> model axis if divisible else replicated
  "mlp"     — ffn hidden / CS group dim -> model axis
  "vocab"   — vocabulary         -> model axis
  "experts" — MoE experts        -> model axis (EP)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.context import constrain


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, std, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": (None,)}


def rmsnorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int):
    return ({"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": (None,), "bias": (None,)})


def layernorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                    # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if x.ndim == ang.ndim + 1:                           # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int):
    params = {"table": normal_init(key, (vocab, d), 0.02)}
    return params, {"table": ("vocab", "embed")}


def embedding_apply(params, tokens, compute_dtype):
    y = jnp.take(params["table"].astype(compute_dtype), tokens, axis=0)
    return constrain(y, "batch", "seq", None)


def lm_head_apply(params, x, compute_dtype):
    """Project to vocab logits; table may be tied (vocab, d)."""
    logits = x @ params["table"].astype(compute_dtype).T
    return constrain(logits, "batch", "seq", "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
