"""The paper's end-to-end GSC keyword-spotting CNN (Table 1), in dense,
sparse-dense and sparse-sparse variants.

Architecture (paper Table 1):
  Input 32x32x1 -> Conv 5x5x64 (VALID) -> MaxPool2 -> Conv 5x5x64 (VALID)
  -> MaxPool2 -> Flatten 1600 -> Linear 1500 -> Output 12

Variant mapping (paper §4.1):
  dense         — everything dense (the Vitis-AI baseline analog).
  sparse-dense  — CS weights on Conv-2 + Linear-1 (+ output), dense
                  activations; Conv-1 left dense (their §4.1 choice).
  sparse-sparse — CS weights + k-WTA activations everywhere downstream;
                  Conv-1 becomes weight-sparse only ('the input to the
                  network is dense, hence sparse-sparse is not an option
                  for Conv-1', §4.1 / §5.4).

Sparsity levels follow the paper: ~95% weights on the big layers
(pack n=16 -> 93.75%, the nearest divisor-compatible level), activations
k-WTA at ~12% winners (88% sparse): conv channel k-WTA k=8/64, global
linear k-WTA k=180/1500.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import DENSE, SparsityConfig
from repro.core.kwta import kwta, kwta_channel
from repro.core.layers import (conv2d_apply, conv2d_init, im2col,
                               linear_apply, linear_init, maxpool2d,
                               packed_conv2d_apply, packed_conv2d_init,
                               packed_linear_apply, packed_linear_init)
from repro.core.masks import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class GSCConfig:
    name: str = "gsc_cnn"
    variant: str = "sparse_sparse"  # dense | sparse_dense | sparse_sparse
    n_classes: int = 12
    channels: int = 64
    hidden: int = 1500
    # CS pack factors (weight density 1/n)
    conv1_n: int = 5                 # 80% sparse stem (paper §5.4 style)
    conv2_n: int = 16                # ~94% sparse
    linear_n: int = 16
    # k-WTA winners
    conv_k: int = 8                  # of 64 channels (~88% sparse)
    linear_k: int = 180              # of 1500 (88% sparse, paper Fig. 10)
    kwta_impl: str = "topk"

    @property
    def weight_sparse(self) -> bool:
        return self.variant in ("sparse_dense", "sparse_sparse")

    @property
    def activation_sparse(self) -> bool:
        return self.variant == "sparse_sparse"

    @property
    def hidden_padded(self) -> int:
        return pad_to_multiple(self.hidden, self.linear_n)


def init_model(key, cfg: GSCConfig) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 4)
    params: Dict = {}
    specs: Dict = {}
    c = cfg.channels
    if cfg.weight_sparse:
        sp1 = SparsityConfig(n=cfg.conv1_n)
        sp2 = SparsityConfig(n=cfg.conv2_n)
        spl = SparsityConfig(n=cfg.linear_n)
        params["conv1"], specs["conv1"] = packed_conv2d_init(
            ks[0], 5, 5, 1, c, sp1, seed=41)
        params["conv2"], specs["conv2"] = packed_conv2d_init(
            ks[1], 5, 5, c, c, sp2, seed=42)
        params["linear"], specs["linear"] = packed_linear_init(
            ks[2], 5 * 5 * c, cfg.hidden_padded, spl, seed=43)
        params["out"], specs["out"] = linear_init(
            ks[3], cfg.hidden_padded, cfg.n_classes)
    else:
        params["conv1"], specs["conv1"] = conv2d_init(ks[0], 5, 5, 1, c)
        params["conv2"], specs["conv2"] = conv2d_init(ks[1], 5, 5, c, c)
        params["linear"], specs["linear"] = linear_init(
            ks[2], 5 * 5 * c, cfg.hidden)
        params["out"], specs["out"] = linear_init(ks[3], cfg.hidden,
                                                  cfg.n_classes)
    return params, specs


def forward(params, x: jax.Array, cfg: GSCConfig) -> jax.Array:
    """x: (B, 32, 32, 1) -> logits (B, n_classes)."""
    c = cfg.channels
    act_sparse = cfg.activation_sparse

    # --- Conv-1 (stem): weight-sparse at most; input is dense (paper §5.4)
    if cfg.weight_sparse:
        sp1 = SparsityConfig(n=cfg.conv1_n)
        h = packed_conv2d_apply(params["conv1"], x, sp1, 5, 5)
    else:
        h = conv2d_apply(params["conv1"], x)
    h = jax.nn.relu(h) if not act_sparse else kwta_channel(
        jax.nn.relu(h), cfg.conv_k)
    h = maxpool2d(h)                                     # (B, 14, 14, 64)

    # --- Conv-2: sparse-sparse heart of the network
    if cfg.weight_sparse:
        sp2 = SparsityConfig(
            n=cfg.conv2_n,
            k_frac=(cfg.conv_k / c) if act_sparse else None)
        h = packed_conv2d_apply(params["conv2"], h, sp2, 5, 5,
                                x_is_sparse=act_sparse)
    else:
        h = conv2d_apply(params["conv2"], h)
    h = jax.nn.relu(h) if not act_sparse else kwta_channel(
        jax.nn.relu(h), cfg.conv_k)
    h = maxpool2d(h)                                     # (B, 5, 5, 64)
    h = h.reshape(h.shape[0], -1)                        # (B, 1600)

    # --- Linear-1 (+ global k-WTA, paper Fig. 10's 1500-element example)
    if cfg.weight_sparse:
        spl = SparsityConfig(
            n=cfg.linear_n,
            k_frac=(cfg.linear_k / cfg.hidden_padded) if act_sparse else None,
            kwta_impl=cfg.kwta_impl)
        h = packed_linear_apply(params["linear"], h, spl)
    else:
        h = linear_apply(params["linear"], h)
    if act_sparse:
        from repro.core.kwta import kwta_hist
        h = jax.nn.relu(h)
        h = (kwta_hist(h, cfg.linear_k) if cfg.kwta_impl == "hist"
             else kwta(h, cfg.linear_k))
    else:
        h = jax.nn.relu(h)

    return linear_apply(params["out"], h)


def loss_fn(params, batch, cfg: GSCConfig):
    logits = forward(params, batch["x"], cfg)
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def theoretical_macs(cfg: GSCConfig) -> Dict[str, float]:
    """Per-sample MAC counts (the paper's Figure 1 accounting)."""
    c, hp = cfg.channels, cfg.hidden_padded
    dense = {
        "conv1": 28 * 28 * c * 25,
        "conv2": 10 * 10 * c * 25 * c,
        "linear": 1600 * cfg.hidden,
        "out": cfg.hidden * cfg.n_classes,
    }
    w = {  # weight sparsity reduction
        "conv1": cfg.conv1_n, "conv2": cfg.conv2_n, "linear": cfg.linear_n,
        "out": 1,
    }
    a = {  # activation sparsity reduction (inputs to each layer)
        "conv1": 1.0,
        "conv2": c / cfg.conv_k,
        "linear": c / cfg.conv_k,
        "out": hp / cfg.linear_k,
    }
    total_dense = sum(dense.values())
    sd = sum(v / w[k] for k, v in dense.items())
    ss = sum(v / (w[k] * a[k]) for k, v in dense.items())
    return {"dense": total_dense, "sparse_dense": sd, "sparse_sparse": ss,
            "speedup_sd": total_dense / sd, "speedup_ss": total_dense / ss}
