"""Continuous-batching scheduler: slot admission, retirement, sampling.

The serving engine (repro/launch/serve.py) holds a fixed-size decode batch
of ``n_slots`` KV-cache slots; this module owns the *policy* side — a FIFO
queue of requests, which slot each admitted request occupies, per-slot
position tracking, and when a slot retires (token budget or EOS).  It is
pure Python + numpy (no jax), so policy is unit-testable without compiling
a model.

Sampling lives here too: greedy and temperature/top-k, applied on host to
the per-slot logits row the engine hands over each step.  Per-request
numpy Generators keep sampling deterministic per request regardless of
which slot the request lands in or what else shares the batch.

Telemetry (ISSUE 8): construct with ``telemetry=repro.obs.Telemetry`` and
the scheduler keeps a full per-request lifecycle record
(:class:`RequestRecord`: enqueue -> admit -> first token -> inter-token
latencies -> finish), feeds the ``serve.*`` histograms/counters, and
emits one ``kind="request"`` JSONL event per retirement.  With the
default (disabled) telemetry every hook degrades to a null-metric call
and the records still accumulate (they are plain Python, ~100 B each).
"""

from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.kvcache import prefix_keys


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy; top_k == 0 -> full-vocab sampling."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: Optional[int] = None


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle of one request through the engine, in seconds relative
    to the serve loop's epoch.  ``itl_*`` aggregate the inter-token
    latencies (gaps between consecutive sampled tokens after the first).

    ``status`` tracks where the request is in its lifecycle
    ("queued" -> "in_flight" -> "finished"), so a metrics snapshot taken
    mid-serve reports requests still decoding instead of silently
    dropping them from the per-request table."""
    uid: int
    t_enqueue: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    n_tokens: int = 0
    itl_sum: float = 0.0
    itl_count: int = 0
    itl_max: float = 0.0
    status: str = "queued"
    preemptions: int = 0            # times evicted and re-queued

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.t_admit - self.t_enqueue)

    @property
    def ttft_s(self) -> float:
        return max(0.0, self.t_first_token - self.t_enqueue)

    def to_event(self) -> Dict:
        """The ``kind="request"`` JSONL event (schema: repro.obs.export)."""
        ev = {"kind": "request", "uid": self.uid,
              "status": self.status,
              "t_enqueue": round(self.t_enqueue, 6),
              "t_admit": round(self.t_admit, 6),
              "t_first_token": round(self.t_first_token, 6),
              "t_finish": round(self.t_finish, 6),
              "n_tokens": self.n_tokens,
              "queue_wait_s": round(self.queue_wait_s, 6),
              "ttft_s": round(self.ttft_s, 6),
              "preemptions": self.preemptions}
        if self.itl_count:
            ev["itl_mean_s"] = round(self.itl_sum / self.itl_count, 6)
            ev["itl_max_s"] = round(self.itl_max, 6)
        return ev


@dataclasses.dataclass
class Slot:
    """One row of the decode batch."""
    index: int
    request: Optional[Request] = None
    pos: int = 0                    # next cache row to be written
    generated: List[int] = dataclasses.field(default_factory=list)
    rng: Optional[np.random.Generator] = None
    admit_time: float = 0.0
    first_token_time: float = 0.0
    last_token_time: float = 0.0
    prefill_pos: int = 0            # prompt tokens already prefilled
    admit_seq: int = -1             # monotonic admission order (LRU key)

    @property
    def busy(self) -> bool:
        return self.request is not None

    @property
    def prefilling(self) -> bool:
        """Chunked prefill in progress: prompt rows not yet all written.
        The slot holds pages but does not join the decode batch until the
        engine finishes feeding its prompt chunks."""
        return (self.request is not None
                and self.prefill_pos < len(self.request.prompt))

    @property
    def done(self) -> bool:
        r = self.request
        if r is None:
            return False
        if self.generated and r.eos_id is not None \
                and self.generated[-1] == r.eos_id:
            return True
        return len(self.generated) >= r.max_new_tokens


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: Optional[np.random.Generator]) -> int:
    """One token from a (vocab,) logits row."""
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    logits = logits.astype(np.float64) / params.temperature
    if params.top_k > 0 and params.top_k < logits.shape[-1]:
        kth = np.partition(logits, -params.top_k)[-params.top_k]
        logits = np.where(logits < kth, -np.inf, logits)
    logits = logits - logits.max()
    probs = np.exp(logits)
    probs /= probs.sum()
    return int(rng.choice(logits.shape[-1], p=probs))


class Scheduler:
    """FIFO admission into a fixed pool of decode slots.

    With ``allocator`` (a :class:`repro.runtime.kvcache.BlockAllocator`)
    admission is additionally gated on KV pages, under one of two
    policies (``kv_policy``):

    * ``"reserve"`` (reserve-on-admit, the PR 9 oracle): the queue head
      needs its worst-case footprint
      ``pages_needed(len(prompt) + max_new_tokens)`` free, reserved in
      full at admit, so decode can never run out of pages mid-request.
    * ``"grow"`` (grow-on-demand): the head needs only
      ``pages_needed(len(prompt))`` — minus any prompt-prefix pages
      already live in the allocator's prefix index, which are adopted
      by reference (``serve.prefix_hit_pages``).  Decode pages are
      allocated lazily by the engine (``BlockAllocator.extend`` at page
      boundaries); when the pool runs dry the engine preempts the
      youngest-admitted slot (:meth:`preemption_victim` /
      :meth:`preempt` — recompute-on-resume: pages released, request
      re-queued at the head with its generated tokens appended to the
      prompt, sampling state stashed so greedy AND stochastic decoding
      resume token-exactly).

    Strict FIFO either way: a blocked head blocks everything behind it
    (no starvation of long prompts by short ones), and preemption evicts
    youngest-first, so a re-queued victim is still older than everything
    behind it.  Retirement releases the chain copy-free.
    """

    def __init__(self, n_slots: int, telemetry=None, allocator=None,
                 kv_policy: str = "reserve"):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if kv_policy not in ("reserve", "grow"):
            raise ValueError(
                f"kv_policy must be 'reserve' or 'grow', got {kv_policy!r}")
        if telemetry is None:
            from repro.obs import Telemetry
            telemetry = Telemetry.off()
        self.telemetry = telemetry
        self.allocator = allocator
        self.kv_policy = kv_policy
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, List[int]] = {}
        self.ttft: Dict[int, float] = {}  # uid -> time of first token
        self.records: Dict[int, RequestRecord] = {}
        self._admit_seq = 0
        # uid -> (generated, rng, first_token_time, last_token_time) of a
        # preempted request, restored verbatim at re-admission so sampling
        # and latency accounting continue as if never evicted
        self._resume: Dict[int, Tuple] = {}
        # uid -> the ORIGINAL prompt, pinned at first preemption: a
        # resumed request's .prompt already embeds the earlier generated
        # tokens, so a second preemption must rebuild from the original
        # (orig + ALL generated), never append to the embedded copy —
        # that would duplicate the first round of tokens in the prompt
        self._orig_prompt: Dict[int, List[int]] = {}
        reg = telemetry.registry
        self._c_submitted = reg.counter("serve.requests_submitted")
        self._c_finished = reg.counter("serve.requests_finished")
        self._c_tokens = reg.counter("serve.tokens_generated")
        self._h_wait = reg.histogram("serve.queue_wait_s")
        self._h_ttft = reg.histogram("serve.ttft_s")
        self._h_itl = reg.histogram("serve.itl_s")
        # windowed twin: recent inter-token latency for long-lived serving
        self._h_itl_recent = reg.rolling_histogram("serve.itl_recent_s")
        self._g_pages_used = reg.gauge("serve.pages_used")
        self._g_pages_free = reg.gauge("serve.pages_free")
        self._g_occupancy = reg.gauge("serve.page_occupancy")
        self._c_preemptions = reg.counter("serve.preemptions")
        self._c_prefix_hits = reg.counter("serve.prefix_hit_pages")
        # plain-int twins of the two counters above: stats and unit tests
        # read these regardless of whether telemetry is enabled
        self.preemption_count = 0
        self.prefix_hit_pages = 0
        self._paranoid = os.environ.get("REPRO_KV_CHECK") == "1"

    def _update_page_gauges(self) -> None:
        if self.allocator is not None:
            self._g_pages_used.set(self.allocator.used_pages)
            self._g_pages_free.set(self.allocator.free_pages)
            self._g_occupancy.set(self.allocator.occupancy)
            if self._paranoid:
                self.allocator.check()

    # -- queue side ---------------------------------------------------------
    def submit(self, request: Request, now: float = 0.0) -> None:
        self.queue.append(request)
        self.records[request.uid] = RequestRecord(uid=request.uid,
                                                  t_enqueue=now)
        self._c_submitted.inc()

    def submit_many(self, requests: Sequence[Request],
                    now: float = 0.0) -> None:
        for r in requests:
            self.submit(r, now=now)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.busy for s in self.slots)

    # -- slot side ----------------------------------------------------------
    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.busy]

    def active_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.busy]

    def decoding_slots(self) -> List[Slot]:
        """Busy slots whose prompt is fully in the cache — the rows that
        participate in this iteration's decode step (chunk-prefilling
        slots sit out until their last chunk lands)."""
        return [s for s in self.slots if s.busy and not s.prefilling]

    def admit(self, now: float = 0.0, chunked: bool = False) -> List[Slot]:
        """Move queued requests into free slots (FIFO). Returns the slots
        that were (re)filled this call; the engine prefills each one.

        ``chunked=True`` admits with ``prefill_pos = 0`` (the engine
        feeds the prompt as paged chunks and advances ``prefill_pos``);
        otherwise the prompt is assumed fused-prefilled at admit, as
        before.  With an allocator, the queue head must also fit the
        free pages (strict FIFO — a blocked head blocks the rest):
        its worst-case footprint under ``kv_policy="reserve"``, just
        its prompt under ``"grow"`` — where prompt-prefix pages already
        in the allocator's index are adopted by reference and skipped
        by chunked prefill (``prefill_pos`` starts past them, capped at
        ``len(prompt) - 1`` so the final logits row is always produced
        by a real chunk forward — an exact-duplicate prompt re-runs its
        last token, whose shared-page write the engine breaks with
        copy-on-write)."""
        admitted = []
        for slot in self.slots:
            if slot.busy or not self.queue:
                continue
            req = self.queue[0]
            shared_rows = 0
            if self.allocator is not None:
                a = self.allocator
                if self.kv_policy == "grow":
                    shared = []
                    if chunked:
                        shared = a.match_prefix(
                            prefix_keys(req.prompt, a.page_size))
                    need = a.pages_needed(len(req.prompt)) - len(shared)
                    if not a.can_allocate(need):
                        break  # head-of-line blocking: keep FIFO order
                    a.allocate(req.uid, need, shared=shared)
                    if shared:
                        self._c_prefix_hits.inc(len(shared))
                        self.prefix_hit_pages += len(shared)
                        shared_rows = min(len(shared) * a.page_size,
                                          len(req.prompt) - 1)
                else:
                    need = a.pages_needed(
                        len(req.prompt) + req.max_new_tokens)
                    if not a.can_allocate(need):
                        break  # head-of-line blocking: keep FIFO order
                    a.allocate(req.uid, need)
            self.queue.popleft()
            slot.request = req
            slot.pos = len(req.prompt)
            slot.generated = []
            slot.rng = np.random.default_rng(req.sampling.seed)
            slot.admit_time = now
            slot.first_token_time = 0.0
            slot.last_token_time = 0.0
            slot.prefill_pos = shared_rows if chunked else len(req.prompt)
            slot.admit_seq = self._admit_seq
            self._admit_seq += 1
            resume = self._resume.pop(req.uid, None)
            if resume is not None:
                (slot.generated, slot.rng, slot.first_token_time,
                 slot.last_token_time) = resume
            rec = self.records.get(req.uid)
            if rec is not None:
                rec.t_admit = now
                rec.status = "in_flight"
                self._h_wait.observe(rec.queue_wait_s)
            admitted.append(slot)
        self._update_page_gauges()
        return admitted

    # -- preemption (kv_policy="grow") --------------------------------------
    def preemption_victim(self, exclude: Sequence[int] = ()) -> \
            Optional[Slot]:
        """The youngest-admitted busy slot (highest ``admit_seq``) not in
        ``exclude`` — the LRU-style eviction choice: it has received the
        least service, so recompute-on-resume re-prefills the fewest
        rows, and re-queueing it at the head preserves global FIFO
        (everything still queued is younger than any admitted slot)."""
        busy = [s for s in self.slots
                if s.busy and s.index not in exclude]
        if not busy:
            return None
        return max(busy, key=lambda s: s.admit_seq)

    def preempt(self, slot: Slot, now: float = 0.0) -> Request:
        """Evict ``slot`` (recompute-on-resume): release its pages, stash
        its sampling state, and re-queue the request AT THE HEAD with the
        tokens generated so far appended to the prompt — on re-admission
        chunked prefill rebuilds the KV rows from the extended prompt
        (token-exact: KV is a pure function of the token prefix) and
        decode continues with the stashed rng, so greedy and stochastic
        outputs both match the never-preempted run.  Returns the
        re-queued request."""
        req = slot.request
        if req is None:
            raise ValueError(f"slot {slot.index} is not busy")
        if self.allocator is not None:
            self.allocator.release(req.uid)
        # slot.generated always holds EVERY token generated so far (the
        # resume stash restores it across evictions), so the rebuilt
        # prompt is original + all-generated even on a repeat preemption
        # of an already-resumed request (whose req.prompt embeds the
        # earlier tokens and must not be appended to again).
        orig = self._orig_prompt.setdefault(req.uid, list(req.prompt))
        resumed = dataclasses.replace(
            req, prompt=list(orig) + list(slot.generated))
        self._resume[req.uid] = (slot.generated, slot.rng,
                                 slot.first_token_time,
                                 slot.last_token_time)
        self.queue.appendleft(resumed)
        rec = self.records.get(req.uid)
        if rec is not None:
            rec.status = "queued"
            rec.preemptions += 1
        self._c_preemptions.inc()
        self.preemption_count += 1
        slot.request = None
        slot.rng = None
        slot.generated = []
        self._update_page_gauges()
        return resumed

    def record_token(self, slot: Slot, token: int, now: float = 0.0) -> None:
        rec = self.records.get(slot.request.uid)
        if not slot.generated:
            slot.first_token_time = now
            self.ttft[slot.request.uid] = now
            if rec is not None:
                rec.t_first_token = now
                self._h_ttft.observe(rec.ttft_s)
        else:
            itl = max(0.0, now - slot.last_token_time)
            self._h_itl.observe(itl)
            self._h_itl_recent.observe(itl)
            if rec is not None:
                rec.itl_sum += itl
                rec.itl_count += 1
                rec.itl_max = max(rec.itl_max, itl)
        slot.last_token_time = now
        slot.generated.append(token)
        if rec is not None:
            rec.n_tokens += 1
        self._c_tokens.inc()

    def retire_done(self, now: float = 0.0) -> List[Slot]:
        """Free every slot whose request finished; their outputs land in
        ``finished`` keyed by request uid. Returns the retired slots (with
        .request still attached for the caller's bookkeeping)."""
        retired = []
        for slot in self.slots:
            if slot.busy and slot.done:
                self.finished[slot.request.uid] = list(slot.generated)
                rec = self.records.get(slot.request.uid)
                if rec is not None:
                    rec.t_finish = now
                    rec.status = "finished"
                    self.telemetry.emit(rec.to_event())
                if self.allocator is not None:
                    self.allocator.release(slot.request.uid)
                self._orig_prompt.pop(slot.request.uid, None)
                self._c_finished.inc()
                retired.append(dataclasses.replace(slot))
                slot.request = None
                slot.rng = None
        if retired:
            self._update_page_gauges()
        return retired
