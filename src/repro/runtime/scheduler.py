"""Continuous-batching scheduler: slot admission, retirement, sampling.

The serving engine (repro/launch/serve.py) holds a fixed-size decode batch
of ``n_slots`` KV-cache slots; this module owns the *policy* side — a FIFO
queue of requests, which slot each admitted request occupies, per-slot
position tracking, and when a slot retires (token budget or EOS).  It is
pure Python + numpy (no jax), so policy is unit-testable without compiling
a model.

Sampling lives here too: greedy and temperature/top-k, applied on host to
the per-slot logits row the engine hands over each step.  Per-request
numpy Generators keep sampling deterministic per request regardless of
which slot the request lands in or what else shares the batch.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy; top_k == 0 -> full-vocab sampling."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Slot:
    """One row of the decode batch."""
    index: int
    request: Optional[Request] = None
    pos: int = 0                    # next cache row to be written
    generated: List[int] = dataclasses.field(default_factory=list)
    rng: Optional[np.random.Generator] = None
    admit_time: float = 0.0
    first_token_time: float = 0.0

    @property
    def busy(self) -> bool:
        return self.request is not None

    @property
    def done(self) -> bool:
        r = self.request
        if r is None:
            return False
        if self.generated and r.eos_id is not None \
                and self.generated[-1] == r.eos_id:
            return True
        return len(self.generated) >= r.max_new_tokens


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: Optional[np.random.Generator]) -> int:
    """One token from a (vocab,) logits row."""
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    logits = logits.astype(np.float64) / params.temperature
    if params.top_k > 0 and params.top_k < logits.shape[-1]:
        kth = np.partition(logits, -params.top_k)[-params.top_k]
        logits = np.where(logits < kth, -np.inf, logits)
    logits = logits - logits.max()
    probs = np.exp(logits)
    probs /= probs.sum()
    return int(rng.choice(logits.shape[-1], p=probs))


class Scheduler:
    """FIFO admission into a fixed pool of decode slots."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, List[int]] = {}
        self.ttft: Dict[int, float] = {}  # uid -> time of first token

    # -- queue side ---------------------------------------------------------
    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def submit_many(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.busy for s in self.slots)

    # -- slot side ----------------------------------------------------------
    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.busy]

    def active_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.busy]

    def admit(self, now: float = 0.0) -> List[Slot]:
        """Move queued requests into free slots (FIFO). Returns the slots
        that were (re)filled this call; the engine prefills each one."""
        admitted = []
        for slot in self.slots:
            if slot.busy or not self.queue:
                continue
            req = self.queue.popleft()
            slot.request = req
            slot.pos = len(req.prompt)
            slot.generated = []
            slot.rng = np.random.default_rng(req.sampling.seed)
            slot.admit_time = now
            slot.first_token_time = 0.0
            admitted.append(slot)
        return admitted

    def record_token(self, slot: Slot, token: int, now: float = 0.0) -> None:
        if not slot.generated:
            slot.first_token_time = now
            self.ttft[slot.request.uid] = now
        slot.generated.append(token)

    def retire_done(self) -> List[Slot]:
        """Free every slot whose request finished; their outputs land in
        ``finished`` keyed by request uid. Returns the retired slots (with
        .request still attached for the caller's bookkeeping)."""
        retired = []
        for slot in self.slots:
            if slot.busy and slot.done:
                self.finished[slot.request.uid] = list(slot.generated)
                retired.append(dataclasses.replace(slot))
                slot.request = None
                slot.rng = None
        return retired
