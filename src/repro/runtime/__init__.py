"""Runtime: fault tolerance, straggler detection, elastic restart, pipeline
parallelism, continuous-batching scheduling."""

from .monitor import LossGuard, StepEvent, StepMonitor
from .pipeline_parallel import bubble_fraction, pipeline_apply
from .scheduler import (Request, SamplingParams, Scheduler, Slot,
                        sample_token)

__all__ = ["LossGuard", "StepEvent", "StepMonitor", "bubble_fraction",
           "pipeline_apply", "Request", "SamplingParams", "Scheduler",
           "Slot", "sample_token"]
