"""Runtime: fault tolerance, straggler detection, elastic restart, pipeline
parallelism."""

from .monitor import LossGuard, StepEvent, StepMonitor
from .pipeline_parallel import bubble_fraction, pipeline_apply

__all__ = ["LossGuard", "StepEvent", "StepMonitor", "bubble_fraction",
           "pipeline_apply"]
