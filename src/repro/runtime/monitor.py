"""Step-time monitoring and straggler/anomaly detection.

At multi-pod scale the common failure modes are (a) a straggling host
slowing every synchronous step, (b) a hung collective, (c) loss spikes
from data or hardware corruption.  ``StepMonitor`` tracks a step-time EMA
and flags steps above ``straggler_factor`` x EMA; a sustained run of flags
trips ``should_reshard`` (the elastic-restart signal consumed by the train
driver).  ``LossGuard`` flags NaN/exploding losses so the driver can roll
back to the last checkpoint instead of corrupting the run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StepEvent:
    step: int
    duration: float
    flagged: bool


class StepMonitor:
    def __init__(self, straggler_factor: float = 2.5, ema_decay: float = 0.9,
                 warmup_steps: int = 3, trip_after: int = 5):
        self.factor = straggler_factor
        self.decay = ema_decay
        self.warmup = warmup_steps
        self.trip_after = trip_after
        self.ema: Optional[float] = None
        self.events: List[StepEvent] = []
        self._consecutive = 0
        self._n = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> StepEvent:
        assert self._t0 is not None, "stop() without start()"
        dur = time.monotonic() - self._t0
        self._t0 = None
        return self.record(step, dur)

    def record(self, step: int, duration: float) -> StepEvent:
        self._n += 1
        flagged = False
        if self.ema is None:
            self.ema = duration
        else:
            if self._n > self.warmup and duration > self.factor * self.ema:
                flagged = True
                self._consecutive += 1
            else:
                self._consecutive = 0
            # EMA excludes flagged outliers so one straggler doesn't poison
            # the baseline
            if not flagged:
                self.ema = self.decay * self.ema + (1 - self.decay) * duration
        ev = StepEvent(step, duration, flagged)
        self.events.append(ev)
        return ev

    @property
    def should_reshard(self) -> bool:
        """Sustained stragglers -> the driver should checkpoint and rebuild
        the mesh from live devices (elastic restart)."""
        return self._consecutive >= self.trip_after

    def summary(self) -> dict:
        durs = [e.duration for e in self.events]
        if not durs:
            return {}
        return {
            "steps": len(durs),
            "mean_s": sum(durs) / len(durs),
            "ema_s": self.ema,
            "flagged": sum(e.flagged for e in self.events),
            "p50_s": sorted(durs)[len(durs) // 2],
            "max_s": max(durs),
        }


class LossGuard:
    """Rolls back on NaN/inf or explosive loss (> spike_factor x EMA)."""

    def __init__(self, spike_factor: float = 10.0, ema_decay: float = 0.95):
        self.factor = spike_factor
        self.decay = ema_decay
        self.ema: Optional[float] = None

    def check(self, loss: float) -> bool:
        """Returns True if the step is healthy; False -> roll back."""
        import math
        if not math.isfinite(loss):
            return False
        if self.ema is None:
            self.ema = loss
            return True
        if loss > self.factor * max(self.ema, 1e-6) and self.ema > 0:
            return False
        self.ema = self.decay * self.ema + (1 - self.decay) * loss
        return True
