"""Step-time monitoring and straggler/anomaly detection.

At multi-pod scale the common failure modes are (a) a straggling host
slowing every synchronous step, (b) a hung collective, (c) loss spikes
from data or hardware corruption.  ``StepMonitor`` tracks a step-time EMA
and flags steps above ``straggler_factor`` x EMA; a sustained run of flags
trips ``should_reshard`` (the elastic-restart signal consumed by the train
driver).  ``LossGuard`` flags NaN/exploding losses so the driver can roll
back to the last checkpoint instead of corrupting the run.

Both ride the :mod:`repro.obs` metrics registry (ISSUE 8): step durations
land in the ``monitor.step_s`` histogram, flags/rollbacks in counters, the
EMA in a gauge, so a train run and a serve run export through the same
``Registry.snapshot()`` shape.  Pass a shared registry to pool them with
engine telemetry; by default each monitor owns a private one, which keeps
``summary()`` self-contained and the trip/flag semantics unchanged.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

from repro.obs.metrics import Registry


@dataclasses.dataclass
class StepEvent:
    step: int
    duration: float
    flagged: bool


class StepMonitor:
    def __init__(self, straggler_factor: float = 2.5, ema_decay: float = 0.9,
                 warmup_steps: int = 3, trip_after: int = 5,
                 registry: Optional[Registry] = None):
        self.factor = straggler_factor
        self.decay = ema_decay
        self.warmup = warmup_steps
        self.trip_after = trip_after
        self.registry = registry if registry is not None else Registry()
        self._h_step = self.registry.histogram("monitor.step_s")
        self._c_flagged = self.registry.counter("monitor.steps_flagged")
        self._g_ema = self.registry.gauge("monitor.step_ema_s")
        self.ema: Optional[float] = None
        self.events: List[StepEvent] = []
        self._consecutive = 0
        self._n = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> StepEvent:
        assert self._t0 is not None, "stop() without start()"
        dur = time.monotonic() - self._t0
        self._t0 = None
        return self.record(step, dur)

    def record(self, step: int, duration: float) -> StepEvent:
        self._n += 1
        flagged = False
        if self.ema is None:
            self.ema = duration
        else:
            if self._n > self.warmup and duration > self.factor * self.ema:
                flagged = True
                self._consecutive += 1
            else:
                self._consecutive = 0
            # EMA excludes flagged outliers so one straggler doesn't poison
            # the baseline
            if not flagged:
                self.ema = self.decay * self.ema + (1 - self.decay) * duration
        self._h_step.observe(duration)
        if flagged:
            self._c_flagged.inc()
        self._g_ema.set(self.ema)
        ev = StepEvent(step, duration, flagged)
        self.events.append(ev)
        return ev

    @property
    def should_reshard(self) -> bool:
        """Sustained stragglers -> the driver should checkpoint and rebuild
        the mesh from live devices (elastic restart)."""
        return self._consecutive >= self.trip_after

    def summary(self) -> dict:
        """Same keys as ever (steps/mean_s/ema_s/flagged/p50_s/max_s), now
        read back out of the registry snapshot instead of a private list.
        ``p50_s`` is the histogram's interpolated estimate (exact when all
        mass shares a bucket, off by at most one bucket width otherwise)."""
        snap = self.registry.snapshot()
        hist = snap["histograms"].get("monitor.step_s", {"count": 0})
        if not hist.get("count"):
            return {}
        return {
            "steps": hist["count"],
            "mean_s": hist["mean"],
            "ema_s": snap["gauges"].get("monitor.step_ema_s"),
            "flagged": int(snap["counters"].get("monitor.steps_flagged", 0)),
            "p50_s": hist["p50"],
            "max_s": hist["max"],
        }


class LossGuard:
    """Rolls back on NaN/inf or explosive loss (> spike_factor x EMA)."""

    def __init__(self, spike_factor: float = 10.0, ema_decay: float = 0.95,
                 registry: Optional[Registry] = None):
        self.factor = spike_factor
        self.decay = ema_decay
        self.registry = registry if registry is not None else Registry()
        self._g_ema = self.registry.gauge("monitor.loss_ema")
        self._c_rollbacks = self.registry.counter("monitor.loss_rollbacks")
        self.ema: Optional[float] = None

    def check(self, loss: float) -> bool:
        """Returns True if the step is healthy; False -> roll back."""
        import math
        if not math.isfinite(loss):
            self._c_rollbacks.inc()
            return False
        if self.ema is None:
            self.ema = loss
            self._g_ema.set(self.ema)
            return True
        if loss > self.factor * max(self.ema, 1e-6) and self.ema > 0:
            self._c_rollbacks.inc()
            return False
        self.ema = self.decay * self.ema + (1 - self.decay) * loss
        self._g_ema.set(self.ema)
        return True
