"""PagedKV layout: the device-side half of the paged KV cache.

A contiguous decode cache stores leaf ``(B, max_seq, ...)``; the paged
pool stores the same rows as ``(n_pages, page_size, ...)`` with a
per-slot *page table* ``pages: (B, blocks_per_slot) int32`` mapping
logical slot position ``p`` to physical row
``pool[pages[b, p // page_size], p % page_size]``.

Three staged primitives thread this layout through the decode jit —
pure gather/scatter, no host transfer, no new Select (the sparsity
linter checks the paged decode jaxpr like any other entry):

* :func:`paged_view` — gather a slot-contiguous ``(B, view_len, ...)``
  read view of every slot's chain (one ``jnp.take`` per leaf; attention
  runs on the view exactly as it would on a contiguous cache, with the
  same ``col <= pos`` validity mask in slot-logical coordinates).
* :func:`paged_write_rows` — scatter one decode row per slot at its own
  position (the continuous-batching write).  Inactive slots' page-table
  rows are all :data:`NULL_PAGE`, so their stale writes land in the
  null page.
* :func:`paged_write_chunk` — scatter a prefill chunk's rows
  (``C`` consecutive positions of ONE slot); rows past ``chunk_len``
  (bucket padding) are redirected to the null page so they can never
  clobber a neighbouring chain.
* :func:`copy_page` — copy one physical page's rows to another (the
  device half of copy-on-write: the allocator swaps a private page
  into the chain, this moves the shared page's rows over before the
  owner's next write lands).

:class:`PagedKV` carries the static geometry (page size, pool size,
page-table width) and the host-side page-table assembly helpers the
engine uses around the jit boundary.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .allocator import NULL_PAGE

__all__ = ["PagedKV", "copy_page", "paged_view", "paged_write_rows",
           "paged_write_chunk", "NULL_PAGE"]


@dataclasses.dataclass(frozen=True)
class PagedKV:
    """Static geometry of one engine's paged KV cache."""

    page_size: int        #: token rows per physical page
    n_pages: int          #: physical pages in the pool (incl. null page 0)
    blocks_per_slot: int  #: page-table width = ceil(max_seq / page_size)

    @property
    def view_len(self) -> int:
        """Sequence length of the gathered per-slot read view (>= the
        engine's max_seq; attention masks the overhang)."""
        return self.blocks_per_slot * self.page_size

    @classmethod
    def build(cls, max_seq: int, n_slots: int, page_size: int = 16,
              n_pages: Optional[int] = None) -> "PagedKV":
        """Geometry for an engine: ``n_pages`` defaults to full backing
        (every slot can hold max_seq rows, plus the null page) — pass a
        smaller pool to actually decouple KV memory from
        ``max_seq * n_slots`` and let admission gate on free pages."""
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        blocks = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = n_slots * blocks + 1
        if n_pages < blocks + 1:
            raise ValueError(
                f"n_pages={n_pages} cannot back even one max_seq request "
                f"({blocks} pages + the null page)")
        return cls(page_size=page_size, n_pages=n_pages,
                   blocks_per_slot=blocks)

    # -- host-side page-table assembly ------------------------------------
    def empty_tables(self, n_slots: int) -> np.ndarray:
        """(n_slots, blocks_per_slot) page tables, all null."""
        return np.full((n_slots, self.blocks_per_slot), NULL_PAGE,
                       np.int32)

    def set_chain(self, tables: np.ndarray, slot: int,
                  chain: Sequence[int]) -> None:
        """Install a request's chain in ``tables[slot]`` (rest null)."""
        if len(chain) > self.blocks_per_slot:
            raise ValueError(
                f"chain of {len(chain)} pages exceeds the page-table "
                f"width {self.blocks_per_slot}")
        tables[slot, :] = NULL_PAGE
        tables[slot, :len(chain)] = np.asarray(chain, np.int32)

    def clear_chain(self, tables: np.ndarray, slot: int) -> None:
        """Point a retired slot's page table back at the null page."""
        tables[slot, :] = NULL_PAGE

    def chunk_spans(self, n_tokens: int, chunk: int) -> List[tuple]:
        """Split a prompt into page-aligned prefill chunks:
        ``[(start, length), ...]`` with every start a multiple of
        ``chunk`` (itself a multiple of page_size) and lengths summing
        to ``n_tokens``."""
        if chunk < 1 or chunk % self.page_size:
            raise ValueError(
                f"prefill chunk {chunk} must be a positive multiple of "
                f"page_size {self.page_size}")
        return [(s, min(chunk, n_tokens - s))
                for s in range(0, n_tokens, chunk)]


# ---------------------------------------------------------------------------
# Staged gather/scatter (jax; imported lazily by the model code)
# ---------------------------------------------------------------------------

def paged_view(pool, pages):
    """Gather the slot-contiguous read view.

    pool:  (n_pages, page_size, ...)
    pages: (B, n_blocks) int32 page table
    ->     (B, n_blocks * page_size, ...)
    """
    import jax.numpy as jnp
    b, n_blk = pages.shape
    v = jnp.take(pool, pages.reshape(-1), axis=0)
    return v.reshape(b, n_blk * pool.shape[1], *pool.shape[2:])


def paged_write_rows(pool, rows, pages, pos):
    """Scatter one row per slot at its own logical position.

    pool:  (n_pages, page_size, ...)
    rows:  (B, ...) — one new cache row per slot
    pages: (B, n_blocks) int32; pos: (B,) int32 logical positions
    """
    import jax.numpy as jnp
    p = pool.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    blk = jnp.clip(pos // p, 0, pages.shape[1] - 1)
    page = jnp.take_along_axis(pages, blk[:, None], axis=1)[:, 0]
    return pool.at[page, pos % p].set(rows.astype(pool.dtype))


def paged_write_chunk(pool, rows, pages_row, pos_start, chunk_len):
    """Scatter a prefill chunk: C consecutive rows of ONE slot.

    pool:      (n_pages, page_size, ...)
    rows:      (C, ...) — the chunk's new cache rows
    pages_row: (n_blocks,) int32 — the prefilling slot's page table
    pos_start: scalar int32 — absolute position of the chunk's first row
    chunk_len: scalar int32 — true rows; rows past it are bucket padding
               and are redirected to the null page.
    """
    import jax.numpy as jnp
    p = pool.shape[1]
    c = rows.shape[0]
    j = jnp.arange(c, dtype=jnp.int32)
    pos = jnp.asarray(pos_start, jnp.int32) + j
    blk = jnp.clip(pos // p, 0, pages_row.shape[0] - 1)
    page = jnp.where(j < chunk_len, pages_row[blk], NULL_PAGE)
    return pool.at[page, pos % p].set(rows.astype(pool.dtype))


def copy_page(pool, src, dst):
    """Copy page ``src``'s rows over page ``dst`` (copy-on-write break).

    pool: (n_pages, page_size, ...); src/dst: scalar int32 page ids.
    One gather + one scatter per leaf, jit-safe with traced ids.
    """
    return pool.at[dst].set(pool[src])
