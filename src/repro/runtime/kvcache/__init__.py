"""Paged/block KV-cache subsystem for the continuous-batching engine.

Decouples KV memory from ``max_seq * n_slots``: requests are admitted
against a pool of fixed-size pages (:class:`BlockAllocator`), every
slot addresses its pages through a per-slot page table threaded into
the decode jit (:mod:`repro.runtime.kvcache.layout`), and long prompts
prefill in page-aligned chunks interleaved with decode steps
(``Engine(kv_layout="paged")`` in :mod:`repro.launch.serve`).

See ``src/repro/runtime/README.md`` for the layout, admission policy,
and chunked-prefill schedule.
"""

from .allocator import NULL_PAGE, BlockAllocator, prefix_keys
from .layout import (PagedKV, copy_page, paged_view, paged_write_chunk,
                     paged_write_rows)

__all__ = ["BlockAllocator", "NULL_PAGE", "PagedKV", "copy_page",
           "paged_view", "paged_write_rows", "paged_write_chunk",
           "prefix_keys"]
