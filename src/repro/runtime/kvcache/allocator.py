"""Block-table allocator: fixed-size KV pages with per-request chains.

The host-side half of the paged KV cache (the device-side half is
:mod:`repro.runtime.kvcache.layout`).  The pool is ``n_pages`` physical
pages of ``page_size`` token rows each; a request is admitted with a
*chain* — an ordered list of page ids covering its worst-case length
(prompt + max_new_tokens, the reserve-on-admit policy) — and logical
slot position ``p`` lives in chain page ``p // page_size`` at row
``p % page_size``.

Design points:

* **Page 0 is the null page** and is never allocated.  Retired slots'
  page-table rows point at it, so a stale decode write from an inactive
  batch row lands in memory nobody reads instead of a page that may
  already belong to a new request.
* **Free list is LIFO** (recently freed pages are re-issued first) —
  keeps the hot working set small and makes use-after-free bugs loud in
  tests.
* **Copy-free reclamation**: ``release`` just returns the chain to the
  free list.  No page is zeroed or copied: the next owner's attention
  mask only ever covers positions its own prefill/decode already wrote
  (``col <= pos``), so stale rows from the previous owner are
  unreachable by construction (the parity tests pin this down).

Pure Python — no jax — so allocation policy is unit/property-testable
without compiling a model.
"""

from __future__ import annotations

from typing import Dict, List

__all__ = ["NULL_PAGE", "BlockAllocator"]

#: Physical page id reserved as the write sink for inactive slots and
#: padded chunk rows; never handed out by the allocator, never read by
#: any active slot's gather (its page-table entries are all real pages
#: up to the chain length, and positions past the chain are masked).
NULL_PAGE = 0


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV pages."""

    def __init__(self, n_pages: int, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page {NULL_PAGE} is the reserved "
                f"null page), got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list over pages [1, n_pages); page 0 stays reserved.
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self._chains: Dict[int, List[int]] = {}

    # -- accounting -----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is not allocatable)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.capacity - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_pages / self.capacity

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` rows (>= 1 even for empty)."""
        return max(1, -(-n_tokens // self.page_size))

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def chain(self, uid: int) -> List[int]:
        """The live chain of ``uid`` (copy), for page-table assembly."""
        return list(self._chains[uid])

    def live_uids(self) -> List[int]:
        return sorted(self._chains)

    # -- alloc / free -----------------------------------------------------
    def allocate(self, uid: int, n: int) -> List[int]:
        """Reserve an ``n``-page chain for ``uid``.  Raises on double
        allocation or insufficient free pages (callers gate admission
        with :meth:`can_allocate`)."""
        if uid in self._chains:
            raise ValueError(f"request {uid} already holds a chain")
        if n < 1:
            raise ValueError(f"chain must be >= 1 page, got {n}")
        if n > len(self._free):
            raise MemoryError(
                f"request {uid} needs {n} pages, only "
                f"{len(self._free)} free")
        chain = [self._free.pop() for _ in range(n)]
        self._chains[uid] = chain
        return list(chain)

    def extend(self, uid: int, n_more: int) -> List[int]:
        """Append ``n_more`` pages to ``uid``'s chain (for future
        speculative/beam growth; unused by reserve-on-admit serving)."""
        if uid not in self._chains:
            raise KeyError(f"request {uid} holds no chain")
        if n_more > len(self._free):
            raise MemoryError(
                f"request {uid} needs {n_more} more pages, only "
                f"{len(self._free)} free")
        new = [self._free.pop() for _ in range(n_more)]
        self._chains[uid].extend(new)
        return list(new)

    def release(self, uid: int) -> List[int]:
        """Return ``uid``'s whole chain to the free list (copy-free: the
        pages are not touched).  Returns the reclaimed page ids."""
        chain = self._chains.pop(uid, None)
        if chain is None:
            raise KeyError(f"request {uid} holds no chain")
        self._free.extend(chain)
        return chain

    # -- invariant check (tests call this after every step) ---------------
    def check(self) -> None:
        """Assert structural invariants: no double-assignment, full
        conservation, null page never issued."""
        live = [p for c in self._chains.values() for p in c]
        assert NULL_PAGE not in live, "null page was allocated"
        assert NULL_PAGE not in self._free, "null page on the free list"
        seen = set(live)
        assert len(seen) == len(live), "page in two chains"
        assert not (seen & set(self._free)), "page both live and free"
        assert len(live) + len(self._free) == self.capacity, \
            "pages leaked or invented"
