"""Block-table allocator: fixed-size KV pages with per-request chains.

The host-side half of the paged KV cache (the device-side half is
:mod:`repro.runtime.kvcache.layout`).  The pool is ``n_pages`` physical
pages of ``page_size`` token rows each; a request holds a *chain* — an
ordered list of page ids — and logical slot position ``p`` lives in
chain page ``p // page_size`` at row ``p % page_size``.

Two admission policies sit on top of this allocator (the scheduler
chooses; see ``runtime/scheduler.py``):

* **reserve-on-admit** (the PR 9 oracle): the chain covers the
  worst-case length ``prompt + max_new_tokens`` in full at admission,
  so decode can never run dry mid-request.
* **grow-on-demand** (the default serving policy): the chain covers
  only ``pages_needed(len(prompt))`` at admission and
  :meth:`BlockAllocator.extend` appends decode pages lazily at page
  boundaries; pool exhaustion is handled by preemption
  (recompute-on-resume) in the serve loop, not by head-of-line
  over-reservation.

Design points:

* **Page 0 is the null page** and is never allocated.  Retired slots'
  page-table rows point at it, so a stale decode write from an inactive
  batch row lands in memory nobody reads instead of a page that may
  already belong to a new request.
* **Free list is LIFO** (recently freed pages are re-issued first) —
  keeps the hot working set small and makes use-after-free bugs loud in
  tests.
* **Pages are ref-counted** so chains can *share* physical pages:
  :meth:`allocate` takes a ``shared=`` prefix of already-live pages
  (prompt-prefix sharing, matched through the prefix index below),
  :meth:`fork` clones a whole chain by reference, and
  :meth:`cow_page` breaks sharing copy-on-write style — the caller
  copies the device rows, the allocator swaps in a private page.  A
  page returns to the free list only when its last holder releases it.
* **Copy-free reclamation**: ``release`` decrements refcounts and
  returns only orphaned pages to the free list.  No page is zeroed or
  copied: the next owner's attention mask only ever covers positions
  its own prefill/decode already wrote (``col <= pos``), so stale rows
  from the previous owner are unreachable by construction (the parity
  tests pin this down).
* **Prefix index**: content-hash keys (:func:`prefix_keys`) map a
  prompt's pages to live physical pages so a later request with the
  same prefix shares them instead of recomputing prefill.  Entries are
  registered by the engine once the rows are actually written and are
  dropped the moment the page is freed, so a match can never point at
  reclaimed or unwritten memory.

Pure Python — no jax — so allocation policy is unit/property-testable
without compiling a model.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["NULL_PAGE", "BlockAllocator", "prefix_keys"]

#: Physical page id reserved as the write sink for inactive slots and
#: padded chunk rows; never handed out by the allocator, never read by
#: any active slot's gather (its page-table entries are all real pages
#: up to the chain length, and positions past the chain are masked).
NULL_PAGE = 0


def prefix_keys(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Content keys for the pages a prompt occupies, aligned with the
    chain: key ``i`` identifies the *content* of chain page ``i``.

    A KV row at position ``p`` is a pure (causal) function of tokens
    ``[0, p]``, so a *full* page ``i`` is keyed by the token prefix
    through its last row, ``tokens[:(i + 1) * page_size]``.  The
    trailing *partial* page (when ``len(tokens) % page_size != 0``) is
    keyed by the exact ``(length, tokens)`` pair — only an identical
    prompt may share it, and the sharer must copy-on-write before its
    own writes land there.  Returns ``pages_needed(len(tokens))`` keys.

    Keys are 128-bit truncations of a SHA-256 over the little-endian
    int64 token bytes (one running hash, extended page by page, so the
    whole prompt is digested once).  The builtin ``hash()`` would NOT
    do: a 64-bit collision between two distinct prompts makes a later
    request silently adopt the wrong live KV pages and emit wrong
    tokens — undetectable by :meth:`BlockAllocator.check` — so the
    content key must be collision-resistant by construction.
    """
    toks = [int(t) for t in tokens]
    n = len(toks)
    keys: List[bytes] = []
    run = hashlib.sha256()
    for i in range(n // page_size):
        for t in toks[i * page_size:(i + 1) * page_size]:
            run.update(t.to_bytes(8, "little", signed=True))
        keys.append(b"p" + run.digest()[:16])
    if n % page_size:
        tail = run.copy()
        tail.update(b"tail:%d:" % n)
        for t in toks[(n // page_size) * page_size:]:
            tail.update(t.to_bytes(8, "little", signed=True))
        keys.append(b"t" + tail.digest()[:16])
    return keys


class BlockAllocator:
    """Ref-counted free-list allocator over a fixed pool of KV pages."""

    def __init__(self, n_pages: int, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page {NULL_PAGE} is the reserved "
                f"null page), got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list over pages [1, n_pages); page 0 stays reserved.
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self._chains: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}         # live page -> holder count
        self._prefix: Dict[bytes, int] = {}    # content key -> live page
        self._page_key: Dict[int, bytes] = {}  # live page -> content key

    # -- accounting -----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is not allocatable)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.capacity - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_pages / self.capacity

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` rows.  Zero tokens need zero
        pages — an empty chain is legal under grow-on-demand (the chain
        grows before the first write); the old ``max(1, ...)`` made
        every empty-prompt admit burn a page for nothing."""
        if n_tokens <= 0:
            return 0
        return -(-n_tokens // self.page_size)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def chain(self, uid: int) -> List[int]:
        """The live chain of ``uid`` (copy), for page-table assembly."""
        return list(self._chains[uid])

    def chain_len(self, uid: int) -> int:
        return len(self._chains[uid])

    def live_uids(self) -> List[int]:
        return sorted(self._chains)

    def page_ref(self, page: int) -> int:
        """Holder count of ``page`` (0 if free)."""
        return self._ref.get(page, 0)

    def page_shared(self, uid: int, block_idx: int) -> bool:
        """True when chain page ``block_idx`` of ``uid`` is held by more
        than one chain — a write there must :meth:`cow_page` first."""
        return self._ref[self._chains[uid][block_idx]] > 1

    # -- alloc / free -----------------------------------------------------
    def allocate(self, uid: int, n: int,
                 shared: Sequence[int] = ()) -> List[int]:
        """Build a chain for ``uid``: the ``shared`` pages by reference
        (refcount bumped; they stay owned by their other holders) plus
        ``n`` fresh pages from the free list.  ``n == 0`` with no shared
        pages yields a legal empty chain (grow-on-demand admits an
        empty prompt without burning a page).  Raises on double
        allocation or insufficient free pages (callers gate admission
        with :meth:`can_allocate`)."""
        if uid in self._chains:
            raise ValueError(f"request {uid} already holds a chain")
        if n < 0:
            raise ValueError(f"fresh page count must be >= 0, got {n}")
        for p in shared:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"shared page {p} is not live")
        if n > len(self._free):
            raise MemoryError(
                f"request {uid} needs {n} pages, only "
                f"{len(self._free)} free")
        chain = []
        for p in shared:
            self._ref[p] += 1
            chain.append(p)
        for _ in range(n):
            p = self._free.pop()
            self._ref[p] = 1
            chain.append(p)
        self._chains[uid] = chain
        return list(chain)

    def extend(self, uid: int, n_more: int) -> List[int]:
        """Append ``n_more`` fresh pages to ``uid``'s chain — the
        grow-on-demand decode path, called at page boundaries.  On
        exhaustion raises ``MemoryError`` with the chain untouched (the
        caller preempts a victim and retries)."""
        if uid not in self._chains:
            raise KeyError(f"request {uid} holds no chain")
        if n_more < 0:
            raise ValueError(f"n_more must be >= 0, got {n_more}")
        if n_more > len(self._free):
            raise MemoryError(
                f"request {uid} needs {n_more} more pages, only "
                f"{len(self._free)} free")
        new = []
        for _ in range(n_more):
            p = self._free.pop()
            self._ref[p] = 1
            new.append(p)
        self._chains[uid].extend(new)
        return list(new)

    def fork(self, parent_uid: int, child_uid: int) -> List[int]:
        """Clone ``parent_uid``'s whole chain by reference for
        ``child_uid`` (every page's refcount bumped; no rows copied).
        Writers on either side must :meth:`cow_page` before touching a
        shared page."""
        if parent_uid not in self._chains:
            raise KeyError(f"request {parent_uid} holds no chain")
        if child_uid in self._chains:
            raise ValueError(f"request {child_uid} already holds a chain")
        chain = list(self._chains[parent_uid])
        for p in chain:
            self._ref[p] += 1
        self._chains[child_uid] = chain
        return list(chain)

    def cow_page(self, uid: int, block_idx: int) -> Optional[Tuple[int, int]]:
        """Break sharing of chain page ``block_idx`` before a write:
        if the page is uniquely held, returns ``None`` (write in
        place); otherwise swaps a fresh private page into the chain and
        returns ``(old_page, new_page)`` — the CALLER must copy the
        device rows old -> new before writing.  The old page stays live
        with its remaining holders (and its prefix-index entry)."""
        chain = self._chains[uid]
        old = chain[block_idx]
        if self._ref[old] == 1:
            return None
        if not self._free:
            raise MemoryError(
                f"request {uid} needs a private copy of page {old}, "
                "no pages free")
        new = self._free.pop()
        self._ref[new] = 1
        self._ref[old] -= 1
        chain[block_idx] = new
        return old, new

    def release(self, uid: int) -> List[int]:
        """Drop ``uid``'s chain: every page's refcount is decremented
        and orphaned pages return to the free list untouched (copy-free
        — stale rows are unreachable through any other chain's mask).
        Returns the pages actually reclaimed (shared pages survive with
        their other holders)."""
        chain = self._chains.pop(uid, None)
        if chain is None:
            raise KeyError(f"request {uid} holds no chain")
        freed = []
        for p in chain:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._drop_prefix_entry(p)
                self._free.append(p)
                freed.append(p)
        return freed

    # -- prefix sharing ----------------------------------------------------
    def _drop_prefix_entry(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            del self._prefix[key]

    def register_prefix(self, key: bytes, page: int) -> bool:
        """Publish ``page`` as the holder of content ``key`` so later
        admissions can share it.  First writer wins: an existing entry
        for the key (or a page already published under another key) is
        left alone.  The page must be live — callers register only
        after the rows are actually written."""
        if self._ref.get(page, 0) < 1:
            raise ValueError(f"page {page} is not live")
        if key in self._prefix or page in self._page_key:
            return False
        self._prefix[key] = page
        self._page_key[page] = key
        return True

    def register_chain_prefix(self, uid: int,
                              keys: Sequence[bytes]) -> int:
        """Register ``uid``'s chain pages under their content keys
        (:func:`prefix_keys` of the prompt, computed by the caller once
        prefill has written the rows).  Returns how many new entries
        were published."""
        chain = self._chains[uid]
        published = 0
        for i, key in enumerate(keys):
            if i >= len(chain):
                break
            published += bool(self.register_prefix(key, chain[i]))
        return published

    def match_prefix(self, keys: Sequence[bytes]) -> List[int]:
        """Longest run of live indexed pages covering ``keys`` from the
        start — the pages a new admission can adopt as its shared chain
        prefix (refcounts are bumped by :meth:`allocate`, not here)."""
        out: List[int] = []
        for key in keys:
            page = self._prefix.get(key)
            if page is None:
                break
            out.append(page)
        return out

    # -- invariant check (tests call this after every step) ---------------
    def check(self) -> None:
        """Assert structural invariants: refcount conservation (every
        live page's count equals the number of chains holding it), no
        page both live and free, full pool conservation, null page
        never issued, and prefix-index consistency (every entry points
        at a live page, maps mutually inverse)."""
        counted: Dict[int, int] = {}
        for uid, chain in self._chains.items():
            assert len(set(chain)) == len(chain), \
                f"chain {uid} holds a page twice"
            for p in chain:
                assert p != NULL_PAGE, "null page was allocated"
                counted[p] = counted.get(p, 0) + 1
        assert counted == self._ref, \
            f"refcount drift: counted {counted} != tracked {self._ref}"
        live = set(counted)
        free = set(self._free)
        assert NULL_PAGE not in free, "null page on the free list"
        assert len(free) == len(self._free), "page twice on the free list"
        assert not (live & free), "page both live and free"
        assert len(live) + len(free) == self.capacity, \
            "pages leaked or invented"
        assert self._prefix == {k: p for p, k in self._page_key.items()}, \
            "prefix index maps out of sync"
        for page in self._page_key:
            assert page in self._ref, f"indexed page {page} is not live"
