"""GPipe-style pipeline parallelism over a dedicated mesh axis, built with
``shard_map`` + ``ppermute``.

The stacked stage params (leading dim = n_stages) shard over the ``pipe``
axis, so each device holds one stage.  A microbatched GPipe schedule runs
``n_micro + n_stages - 1`` ticks; at each tick every stage processes the
activation it holds and ``ppermute`` shifts activations to the next stage.
Bubble fraction = (S-1)/(M+S-1), reported by :func:`bubble_fraction`.

This is the optional PP building block (DESIGN.md §6): the assigned
production mesh is (data, model), but the trainer can carve a ``pipe``
axis for deeper models; tests validate numerics against the unpipelined
reference on a 4-device CPU mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str,
                   stage_params, x, n_micro: int):
    """Run ``x`` through ``n_stages`` of ``stage_fn`` as a GPipe pipeline.

    Args:
      stage_fn: (params_slice, activation) -> activation; applied by every
        stage (homogeneous stages).
      stage_params: pytree with leading dim n_stages on every leaf.
      x: (batch, ...) global input; batch must divide n_micro.
      n_micro: number of microbatches.

    Returns: y with x's shape (the pipeline output of the last stage).
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    if b % n_micro:
        raise ValueError(f"batch {b} must divide n_micro {n_micro}")
    mb = b // n_micro

    def local(params, x_local):
        # params: this stage's slice (leading dim 1); x_local: full batch
        # (replicated input; stage 0 feeds the pipe).
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        micro = x_local.reshape(n_micro, mb, *x_local.shape[1:])
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros((mb, *x_local.shape[1:]), x_local.dtype)
        outs = jnp.zeros((n_micro, mb, *x_local.shape[1:]), x_local.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            feed = micro[jnp.minimum(t, n_micro - 1)]
            buf = jnp.where(stage == 0,
                            jnp.where(t < n_micro, feed, buf), buf)
            y = stage_fn(params, buf)
            # last stage emits microbatch (t - (n_stages - 1))
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # shift activations forward one stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # all-gather the last stage's outputs so every shard returns y
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs.reshape(b, *x_local.shape[1:])

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    from repro.sharding.context import shard_map
    return shard_map(
        local, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
