"""Realized-sparsity telemetry: winner-support capture + path attribution.

The paper's throughput claim rides on the *realized* activation sparsity
at runtime, not the configured k/N (arXiv 2112.13896 §4; arXiv 2311.07625
for the activity-sparse decode regime).  The static linter
(:mod:`repro.analysis`) proves the staged program keeps the sparse-sparse
structure; this module measures what actually flows through it:

* **Support capture** — a trace-time collector that rides along the
  serving engine's *probed* decode step.  ``apply_kwta`` (and the bisect/
  hist datapaths, via an nnz reduction) report each layer's winner set to
  the active capture; :func:`drain_pending`/:func:`emit_stacked` thread
  those arrays through ``lax.scan`` in ``transformer.serve_step`` so the
  per-unit winner indices come back stacked ``(n_units, B, K)`` as extra
  jit outputs.  **When no capture is active every hook is a no-op and the
  staged jaxpr is bit-identical to the un-instrumented one** — the
  telemetry-off path stages nothing (asserted by ``tests/test_obs.py``
  and re-proven by ``repro.analysis`` in CI).
* **SparsityStats** — host-side accumulation over probed steps: realized
  k/N per layer (winners with non-zero value / feature dim; for the
  >=-K threshold impls, the measured keep count), and cross-step winner
  overlap per layer (|support_t ∩ support_{t-1}| / K per slot, reset on
  request admission).
* **DispatchStats** — trace-time execution-path attribution fed by the
  observer hook in :mod:`repro.core.api`: which path (topk / hadamard /
  dense) and backend (pallas / interpret / jnp) each CS layer staged,
  with the kernel cost model (FLOPs = 2·B·K·D_out for the sparse-sparse
  contraction — see ``kernels/topk_gather.py``) and the per-grid-step
  VMEM estimate from :mod:`repro.kernels.block_validation`.  Combined
  with the measured decode stage time this yields the estimated fraction
  of decode wall-time inside the sparse kernel path vs the dense
  fallback (an estimate: one jit can't be timed from inside).

No module here imports :mod:`repro.core` or :mod:`repro.models` — the
hooks point the other way, so the capture can be active while those
modules trace.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["SupportCapture", "capture_supports", "observe_site",
           "observe_support", "observe_activation", "drain_pending",
           "emit_stacked", "capture_active", "SparsityStats",
           "DispatchStats", "est_path_flops"]


# ---------------------------------------------------------------------------
# Trace-time support capture
# ---------------------------------------------------------------------------

class _Tls(threading.local):
    def __init__(self):
        self.capture: Optional["SupportCapture"] = None
        self.sites: List[str] = []


_TLS = _Tls()


class SupportCapture:
    """One probed trace's collected winner sets.

    ``pending`` holds entries observed since the last :func:`drain_pending`
    (i.e. within the current scan-body trace); ``entries``/``meta`` hold
    the post-scan stacked arrays keyed by layer label.
    """

    def __init__(self):
        # [(label, d, kind, (arrays...))] — arrays are jax tracers
        self.pending: List[Tuple[str, int, str, tuple]] = []
        self._drained_meta: List[Tuple[str, int, str]] = []
        self.entries: Dict[str, tuple] = {}
        self.meta: Dict[str, Dict] = {}

    def _label(self, base: str) -> str:
        label = ".".join(_TLS.sites + [base]) if _TLS.sites else base
        k, out = 2, label
        seen = {l for (l, _, _, _) in self.pending} | set(self.entries)
        while out in seen:
            out = f"{label}#{k}"
            k += 1
        return out

    def add(self, base: str, d: int, kind: str, arrays: tuple) -> None:
        self.pending.append((self._label(base), d, kind, arrays))

    def take_arrays(self) -> Dict[str, tuple]:
        """Jit-output pytree: ``{label: (arrays...)}`` (arrays only; the
        static meta travels via :attr:`meta` on the Python side)."""
        return dict(self.entries)


def capture_active() -> bool:
    return _TLS.capture is not None


@contextlib.contextmanager
def capture_supports() -> Iterator[SupportCapture]:
    """Activate a :class:`SupportCapture` for the current thread.

    Wrap the *trace* of the function to probe (the serving engine wraps
    the body of its probed decode-step jit).  Nested captures shadow the
    outer one.
    """
    prev = _TLS.capture
    cap = SupportCapture()
    _TLS.capture = cap
    try:
        yield cap
    finally:
        _TLS.capture = prev


@contextlib.contextmanager
def observe_site(label: str) -> Iterator[None]:
    """Push a site label (e.g. ``b0``, ``ffn``) onto the capture's label
    path.  Cheap enough to wrap every block at trace time unconditionally."""
    _TLS.sites.append(label)
    try:
        yield
    finally:
        _TLS.sites.pop()


def observe_support(vals, idx, d: int, site: str = "kwta") -> None:
    """Report an exact-top-k winner set ``(vals (..., K), idx (..., K))``
    over a ``d``-wide axis.  No-op without an active capture."""
    cap = _TLS.capture
    if cap is None:
        return
    cap.add(site, d, "support", (vals, idx))


def observe_activation(y, site: str = "kwta") -> None:
    """Report a thresholded k-sparse activation with no index form (the
    hist/bisect >=-K datapaths): stages a per-row nnz reduction — only
    when a capture is active, so the un-probed path stays untouched."""
    cap = _TLS.capture
    if cap is None:
        return
    import jax.numpy as jnp
    nnz = jnp.sum((y != 0), axis=-1).astype(jnp.int32)
    cap.add(site, y.shape[-1], "nnz", (nnz,))


def drain_pending() -> tuple:
    """Pull the entries observed inside the current scan-body trace, as a
    tuple suitable for a ``lax.scan`` body output (stacked over the scan
    axis).  Returns ``()`` when no capture is active — the scan output
    pytree gains no leaves and the staged jaxpr is unchanged."""
    cap = _TLS.capture
    if cap is None or not cap.pending:
        return ()
    cap._drained_meta = [(l, d, k) for (l, d, k, _) in cap.pending]
    out = tuple(arrays for (_, _, _, arrays) in cap.pending)
    cap.pending = []
    return out


def emit_stacked(aux: tuple) -> None:
    """Attach the scan-stacked drain outputs back to the capture, keyed by
    the labels recorded at drain time.  No-op when inactive or empty."""
    cap = _TLS.capture
    if cap is None or not aux:
        return
    for (label, d, kind), arrays in zip(cap._drained_meta, aux):
        cap.entries[label] = tuple(arrays)
        cap.meta[label] = {"d": d, "kind": kind}


# ---------------------------------------------------------------------------
# Host-side realized-sparsity accumulation
# ---------------------------------------------------------------------------

class SparsityStats:
    """Accumulates probed-step winner sets into per-layer statistics.

    Layers are keyed ``{label}.u{unit}`` (scan-stacked captures carry a
    leading unit axis).  Per layer: mean realized k/N (non-zero winners /
    feature dim) and mean cross-step winner overlap (support kind only).
    Overlap for a slot row is suppressed until the row has two probed
    steps from the *same* request (:meth:`reset_row` on admission).
    """

    def __init__(self, registry=None):
        from .metrics import NULL_REGISTRY
        self._reg = registry if registry is not None else NULL_REGISTRY
        self._prev_idx: Dict[str, np.ndarray] = {}
        self._row_valid: Optional[np.ndarray] = None
        self._acc: Dict[str, Dict[str, float]] = {}
        self.probes = 0

    def reset_row(self, row: int) -> None:
        """A new request took slot ``row``: don't bridge overlap across it."""
        if self._row_valid is not None and row < self._row_valid.shape[0]:
            self._row_valid[row] = False

    def _layer(self, name: str, d: int, k: int) -> Dict[str, float]:
        a = self._acc.get(name)
        if a is None:
            a = self._acc[name] = {"d": d, "k": k, "realized_sum": 0.0,
                                   "realized_n": 0, "overlap_sum": 0.0,
                                   "overlap_n": 0}
        return a

    def update(self, arrays: Dict[str, tuple], meta: Dict[str, Dict],
               active_rows: Sequence[int]) -> None:
        """Fold one probed step's captured arrays into the accumulators.

        ``arrays``/``meta`` come from the probed jit's aux output and the
        capture's meta dict; ``active_rows`` are the slot rows holding
        live requests this step (idle rows carry stale activations).
        """
        if not arrays or not active_rows:
            return
        self.probes += 1
        active = np.asarray(sorted(active_rows), np.int32)
        realized_fracs, overlap_means = [], []
        for label in sorted(arrays):
            m = meta[label]
            d, kind = int(m["d"]), m["kind"]
            if kind == "support":
                vals = np.asarray(arrays[label][0])
                idx = np.asarray(arrays[label][1])
                if vals.ndim == 2:          # eager capture: no unit axis
                    vals, idx = vals[None], idx[None]
                # collapse any middle dims (decode carries S=1: (U,B,1,K))
                u, k = vals.shape[0], vals.shape[-1]
                vals = vals.reshape(u, -1, k)
                idx = idx.reshape(u, -1, k)
                u, b, k = idx.shape
                if self._row_valid is None or self._row_valid.shape[0] != b:
                    self._row_valid = np.zeros((b,), bool)
                realized = (vals != 0).sum(-1)                    # (U, B)
                prev = self._prev_idx.get(label)
                overlaps = None
                if prev is not None and prev.shape == idx.shape:
                    # row-offset trick: shift each (unit, row) into its own
                    # index space so one np.isin covers the whole batch
                    off = (np.arange(u * b, dtype=np.int64)
                           .reshape(u, b, 1)) * d
                    cur = idx.astype(np.int64) + off
                    old = prev.astype(np.int64) + off
                    hit = np.isin(cur.ravel(), old.ravel())
                    overlaps = hit.reshape(u, b, k).sum(-1) / k   # (U, B)
                self._prev_idx[label] = idx
                for ui in range(u):
                    a = self._layer(f"{label}.u{ui}", d, k)
                    r = realized[ui, active] / d
                    a["realized_sum"] += float(r.sum())
                    a["realized_n"] += int(active.size)
                    realized_fracs.append(float(r.mean()))
                    if overlaps is not None:
                        ok = active[self._row_valid[active]]
                        if ok.size:
                            o = overlaps[ui, ok]
                            a["overlap_sum"] += float(o.sum())
                            a["overlap_n"] += int(ok.size)
                            overlap_means.append(float(o.mean()))
            elif kind == "nnz":
                nnz = np.asarray(arrays[label][0])
                if nnz.ndim == 1:
                    nnz = nnz[None]
                nnz = nnz.reshape(nnz.shape[0], -1)  # (U, B*S), decode S=1
                u, b = nnz.shape
                for ui in range(u):
                    a = self._layer(f"{label}.u{ui}", d, -1)
                    r = nnz[ui, active] / d
                    a["realized_sum"] += float(r.sum())
                    a["realized_n"] += int(active.size)
                    realized_fracs.append(float(r.mean()))
        if self._row_valid is not None:
            self._row_valid[:] = False
            self._row_valid[active] = True
        if realized_fracs:
            self._reg.gauge("sparsity.realized_k_frac").set(
                float(np.mean(realized_fracs)))
        if overlap_means:
            self._reg.gauge("sparsity.winner_overlap").set(
                float(np.mean(overlap_means)))
        self._reg.counter("sparsity.probe_steps").inc()

    def summary(self) -> Dict[str, Dict]:
        """Per-layer means: ``{layer: {d, k, realized_k_frac,
        winner_overlap, samples}}`` (overlap absent for nnz layers)."""
        out: Dict[str, Dict] = {}
        for name, a in sorted(self._acc.items()):
            e = {"d": int(a["d"]), "samples": int(a["realized_n"])}
            if a["k"] > 0:
                e["k"] = int(a["k"])
                e["configured_k_frac"] = round(a["k"] / a["d"], 6)
            if a["realized_n"]:
                e["realized_k_frac"] = round(
                    a["realized_sum"] / a["realized_n"], 6)
            if a["overlap_n"]:
                e["winner_overlap"] = round(
                    a["overlap_sum"] / a["overlap_n"], 6)
            out[name] = e
        return out


# ---------------------------------------------------------------------------
# Execution-path attribution (trace-time, fed by repro.core.api hook)
# ---------------------------------------------------------------------------

def est_path_flops(ev: Dict) -> float:
    """Cost model per staged CS layer application (see module docstring)."""
    b, d_in, d_out = ev["batch"], ev["d_in"], ev["d_out"]
    if ev["path"] == "topk":
        return 2.0 * b * ev.get("k", d_in) * d_out
    if ev["path"] == "dense":
        return 2.0 * b * d_in * d_out
    return 2.0 * b * d_in * d_out / max(1, ev.get("n", 1))  # hadamard


def _est_topk_vmem(ev: Dict) -> int:
    """Per-grid-step VMEM estimate for the topk_gather kernel's resident
    blocks under its default (nG, B) grid with block_g = G (matches the
    BlockSpecs in ``kernels/topk_gather.py``), via the shared estimator in
    ``kernels/block_validation``."""
    from repro.kernels.block_validation import estimate_vmem_bytes
    n = max(1, ev.get("n", 1))
    k = ev.get("k", ev["d_in"])
    g, p = ev["d_out"] // n, ev["d_in"] // n
    return estimate_vmem_bytes([
        ((1, k), np.float32), ((1, k), np.int32), ((1, k), np.int32),
        ((p, g, n), np.float32), ((p, g, n), np.int8),
        ((1, g * n), np.float32),
    ])


class DispatchStats:
    """Records the execution-path decision of every CS layer staged while
    unsealed (the engine seals after the first decode-step trace, so the
    site list describes exactly one staged decode step; ``lax.scan``
    bodies count once — shares are unaffected when all sparse layers live
    in the unit scan, which is the repro's layout)."""

    def __init__(self):
        self.sites: List[Dict] = []
        self._sealed = False
        self._lock = threading.Lock()

    def on_event(self, ev: Dict) -> None:
        with self._lock:
            if not self._sealed:
                self.sites.append(dict(ev))

    def seal(self) -> None:
        with self._lock:
            self._sealed = True

    @property
    def sealed(self) -> bool:
        return self._sealed

    def summary(self, decode_total_s: Optional[float] = None) -> Dict:
        """Aggregate by path+backend with est-FLOP shares; with a measured
        decode stage total, also the estimated wall-time split."""
        agg: Dict[str, Dict] = {}
        total = 0.0
        sparse = 0.0
        for ev in self.sites:
            backend = ("pallas-interpret" if ev.get("interpret")
                       else "pallas") if ev.get("pallas") else "jnp"
            key = f"{ev['path']}[{backend}]"
            a = agg.setdefault(key, {"sites": 0, "est_flops": 0.0})
            fl = est_path_flops(ev)
            a["sites"] += 1
            a["est_flops"] += fl
            total += fl
            if ev["path"] == "topk":
                sparse += fl
                if ev.get("pallas"):
                    a.setdefault("est_vmem_bytes", 0)
                    a["est_vmem_bytes"] += _est_topk_vmem(ev)
        out: Dict = {"paths": agg}
        if total > 0:
            frac = sparse / total
            out["sparse_flop_frac_est"] = round(frac, 6)
            if decode_total_s is not None:
                out["decode_sparse_time_est_s"] = round(
                    frac * decode_total_s, 6)
                out["decode_dense_time_est_s"] = round(
                    (1.0 - frac) * decode_total_s, 6)
        return out
