"""Metrics registry: counters, gauges, fixed-bucket latency histograms.

The runtime-observability counterpart of the *static* sparsity linter
(:mod:`repro.analysis`): the linter proves the staged program has the
right structure, this registry measures what the structure *does* at
runtime — request latencies, stage times, queue depths, realized
activation sparsity.  Design constraints (ISSUE 8):

* **Thread-safe** — one lock per registry guards both the name table and
  every update; metric objects are cheap enough that serving-loop call
  sites (a handful of updates per decode step) cost microseconds.
* **Zero-cost when disabled** — a disabled registry hands out shared
  null singletons whose ``inc``/``set``/``observe`` are empty methods, so
  instrumented code needs no ``if telemetry:`` branches and the disabled
  path allocates nothing per call.
* **Snapshot-oriented** — no background threads, no push model:
  ``Registry.snapshot()`` returns a plain nested dict (JSON-ready), the
  thing ``Engine.metrics_snapshot()`` / ``benchmarks/run.py --json``
  embed.

Histograms use fixed bucket edges (default: log-spaced seconds, 100 µs to
~178 s) so that merging/exporting never re-bins; percentiles are
estimated by linear interpolation inside the hit bucket, with the
observed min/max clamping the first/last buckets (exact for the common
"all mass in one bucket" smoke-test case).
"""

from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "RollingHistogram", "Registry",
           "NULL_REGISTRY", "DEFAULT_LATENCY_EDGES_S"]


def _log_edges(lo: float, hi: float, per_decade: int) -> Tuple[float, ...]:
    n = int(math.ceil(per_decade * math.log10(hi / lo))) + 1
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n))


#: Default latency bucket edges (seconds): 4 buckets per decade from
#: 100 µs to ~178 s — spans a fast CPU decode step to a stuck request.
DEFAULT_LATENCY_EDGES_S = _log_edges(1e-4, 100.0, 4)


def _bucket_index(edges: Sequence[float], v: float) -> int:
    """First bucket with ``v <= edge`` (binary search), else overflow."""
    lo, hi = 0, len(edges)
    while lo < hi:
        mid = (lo + hi) // 2
        if v <= edges[mid]:
            hi = mid
        else:
            lo = mid + 1
    return lo


class Counter:
    """Monotonic counter (e.g. tokens generated, prefill calls)."""

    __slots__ = ("name", "unit", "_lock", "_value")

    def __init__(self, name: str, unit: str, lock: threading.Lock):
        self.name = name
        self.unit = unit
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins sample (e.g. queue depth, EMA state)."""

    __slots__ = ("name", "unit", "_lock", "_value")

    def __init__(self, name: str, unit: str, lock: threading.Lock):
        self.name = name
        self.unit = unit
        self._lock = lock
        self._value: Optional[float] = None

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def reset(self) -> None:
        with self._lock:
            self._value = None

    @property
    def value(self) -> Optional[float]:
        return self._value

    def snapshot(self):
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentile snapshots.

    ``edges`` are the bucket upper bounds (ascending); observation ``v``
    lands in the first bucket with ``v <= edge``, or the overflow bucket
    past the last edge.  ``percentile(q)`` linearly interpolates within
    the hit bucket (clamped by observed min/max), which is exact when a
    bucket holds uniform mass and never off by more than one bucket
    width otherwise.
    """

    __slots__ = ("name", "unit", "edges", "_lock", "_counts", "_count",
                 "_sum", "_min", "_max")

    def __init__(self, name: str, unit: str, lock: threading.Lock,
                 edges: Sequence[float] = DEFAULT_LATENCY_EDGES_S):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram {name}: edges must be ascending "
                             f"and non-empty, got {edges!r}")
        self.name = name
        self.unit = unit
        self.edges = tuple(float(e) for e in edges)
        self._lock = lock
        self._counts = [0] * (len(self.edges) + 1)  # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._counts[self._bucket(v)] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.edges) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def _bucket(self, v: float) -> int:
        return _bucket_index(self.edges, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> Optional[float]:
        return self._sum / self._count if self._count else None

    def percentile(self, q: float) -> Optional[float]:
        """Interpolated q-th percentile (q in [0, 100])."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        if self._count == 0:
            return None
        target = q / 100.0 * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self._max
                # clamp by observed extrema (exact one-bucket case)
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self._max

    def snapshot(self) -> Dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0}
            return {
                "count": self._count,
                "sum": self._sum,
                "mean": self._sum / self._count,
                "min": self._min,
                "max": self._max,
                "p50": self.percentile(50.0),
                "p95": self.percentile(95.0),
                "p99": self.percentile(99.0),
            }


class RollingHistogram:
    """Windowed percentiles: a ring of time-sliced sub-histograms.

    A run-lifetime :class:`Histogram` answers "how has latency been since
    start"; long-lived serving wants "how is latency NOW".  The window
    ``[now - window_s, now)`` is covered by ``n_slices`` sub-histograms
    of ``window_s / n_slices`` seconds each: ``observe`` lands in the
    slice owning the current instant (lazily zeroing a slice whose old
    epoch has expired — O(1) per observation, no background thread), and
    ``percentile``/``snapshot`` merge only the slices still inside the
    window.  Old mass thus ages out with slice granularity instead of
    accumulating forever, at a fixed memory cost of
    ``n_slices × len(edges)`` ints.

    ``clock`` is injectable (tests drive a fake clock; default
    ``time.monotonic``).
    """

    __slots__ = ("name", "unit", "edges", "window_s", "n_slices", "_lock",
                 "_clock", "_slice_s", "_ids", "_counts", "_n", "_sum",
                 "_min", "_max")

    def __init__(self, name: str, unit: str, lock: threading.Lock,
                 edges: Sequence[float] = DEFAULT_LATENCY_EDGES_S,
                 window_s: float = 60.0, n_slices: int = 6, clock=None):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"rolling histogram {name}: edges must be "
                             f"ascending and non-empty, got {edges!r}")
        if window_s <= 0 or n_slices < 1:
            raise ValueError(f"rolling histogram {name}: need window_s > 0 "
                             f"and n_slices >= 1, got {window_s}/{n_slices}")
        self.name = name
        self.unit = unit
        self.edges = tuple(float(e) for e in edges)
        self.window_s = float(window_s)
        self.n_slices = int(n_slices)
        self._lock = lock
        self._clock = clock if clock is not None else time.monotonic
        self._slice_s = self.window_s / self.n_slices
        n = self.n_slices
        self._ids = [-1] * n           # epoch owning each ring position
        self._counts = [[0] * (len(self.edges) + 1) for _ in range(n)]
        self._n = [0] * n
        self._sum = [0.0] * n
        self._min = [math.inf] * n
        self._max = [-math.inf] * n

    def _clear(self, i: int, sid: int) -> None:
        self._ids[i] = sid
        self._counts[i] = [0] * (len(self.edges) + 1)
        self._n[i] = 0
        self._sum[i] = 0.0
        self._min[i] = math.inf
        self._max[i] = -math.inf

    def _slot(self, sid: int) -> int:
        """Ring position for epoch ``sid``, zeroed if a stale epoch
        still occupies it."""
        i = sid % self.n_slices
        if self._ids[i] != sid:
            self._clear(i, sid)
        return i

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = self._slot(int(self._clock() / self._slice_s))
            self._counts[i][_bucket_index(self.edges, v)] += 1
            self._n[i] += 1
            self._sum[i] += v
            self._min[i] = min(self._min[i], v)
            self._max[i] = max(self._max[i], v)

    def reset(self) -> None:
        with self._lock:
            for i in range(self.n_slices):
                # -1 can sit inside the live window while sid < n_slices
                # (start of a run), so the slice state must be zeroed too
                self._clear(i, -1)

    def _merged(self):
        """(counts, count, sum, min, max) over the live window."""
        sid = int(self._clock() / self._slice_s)
        counts = [0] * (len(self.edges) + 1)
        n, s, mn, mx = 0, 0.0, math.inf, -math.inf
        for i in range(self.n_slices):
            if not (sid - self.n_slices < self._ids[i] <= sid):
                continue  # expired (or never-written) slice
            for b, c in enumerate(self._counts[i]):
                counts[b] += c
            n += self._n[i]
            s += self._sum[i]
            mn = min(mn, self._min[i])
            mx = max(mx, self._max[i])
        return counts, n, s, mn, mx

    @property
    def count(self) -> int:
        with self._lock:
            return self._merged()[1]

    def _pct(self, counts, n, mn, mx, q: float) -> Optional[float]:
        if n == 0:
            return None
        target = q / 100.0 * n
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else mx
                lo = max(lo, mn)
                hi = min(hi, mx)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return mx

    def percentile(self, q: float) -> Optional[float]:
        """Interpolated q-th percentile over the live window only."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        with self._lock:
            counts, n, _, mn, mx = self._merged()
        return self._pct(counts, n, mn, mx, q)

    def snapshot(self) -> Dict:
        with self._lock:
            counts, n, s, mn, mx = self._merged()
        if n == 0:
            return {"count": 0, "window_s": self.window_s}
        return {"count": n, "window_s": self.window_s, "sum": s,
                "mean": s / n, "min": mn, "max": mx,
                "p50": self._pct(counts, n, mn, mx, 50.0),
                "p95": self._pct(counts, n, mn, mx, 95.0),
                "p99": self._pct(counts, n, mn, mx, 99.0)}


class _NullMetric:
    """Shared do-nothing metric: the disabled-registry hand-out.

    All three update verbs are empty methods on one singleton, so
    instrumented code pays one attribute call and nothing else when
    telemetry is off.
    """

    __slots__ = ()
    name = ""
    unit = ""
    value = None
    count = 0
    sum = 0.0
    mean = None

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass

    def percentile(self, q: float) -> Optional[float]:
        return None

    def snapshot(self):
        return None


_NULL_METRIC = _NullMetric()


class Registry:
    """Named metrics table: ``counter``/``gauge``/``histogram`` create or
    return (idempotent per name; kind mismatches raise), ``snapshot()``
    serializes everything.  A disabled registry returns the shared null
    metric from every accessor and snapshots empty."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind, **kw):
        if not self.enabled:
            return _NULL_METRIC
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = kind(name, lock=self._lock, **kw)
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get(name, Counter, unit=unit)

    def gauge(self, name: str, unit: str = "") -> Gauge:
        return self._get(name, Gauge, unit=unit)

    def histogram(self, name: str, unit: str = "s",
                  edges: Sequence[float] = DEFAULT_LATENCY_EDGES_S
                  ) -> Histogram:
        return self._get(name, Histogram, unit=unit, edges=edges)

    def rolling_histogram(self, name: str, unit: str = "s",
                          edges: Sequence[float] = DEFAULT_LATENCY_EDGES_S,
                          window_s: float = 60.0, n_slices: int = 6,
                          clock=None) -> RollingHistogram:
        """Windowed-percentile histogram (see :class:`RollingHistogram`).
        Construction kwargs apply on first registration only (idempotent
        per name, like every accessor)."""
        return self._get(name, RollingHistogram, unit=unit, edges=edges,
                         window_s=window_s, n_slices=n_slices, clock=clock)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every registered metric, keeping registrations (handles
        stay valid).  Benchmarks use this between warm-up and the timed
        run so compile-laden warm-up requests don't pollute percentiles."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def snapshot(self) -> Dict[str, Dict]:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}``."""
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        if not self.enabled:
            return out
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.snapshot()
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.snapshot()
            else:
                # Histogram and RollingHistogram both serve percentile
                # snapshots (the rolling one over its live window only).
                out["histograms"][name] = m.snapshot()
        return out


#: Process-wide disabled registry: the default for un-telemetered code.
NULL_REGISTRY = Registry(enabled=False)
