"""Span tracer: nested wall-clock spans with a thread-local stack.

``Tracer.span("decode.step")`` times a ``with`` block and records one
event per exit: name, start time (relative to the tracer's epoch),
duration, nesting depth, parent span name, plus any keyword attributes.
Events accumulate in an in-memory ring (``max_events``) and, when a sink
is attached (:class:`repro.obs.export.JsonlWriter`), stream out as JSON
lines in the schema :mod:`repro.obs.export` validates.

The stack is thread-local, so spans opened on different threads nest
independently; per-stage totals (``totals()``) aggregate across threads.

Disabled tracers are zero-cost: ``span()`` returns one shared re-entrant
null context manager — no allocation, no clock read, no event.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["SpanEvent", "Tracer", "NULL_TRACER"]


class SpanEvent:
    __slots__ = ("name", "t_start", "dur_s", "depth", "parent", "attrs")

    def __init__(self, name: str, t_start: float, dur_s: float, depth: int,
                 parent: Optional[str], attrs: Optional[dict]):
        self.name = name
        self.t_start = t_start
        self.dur_s = dur_s
        self.depth = depth
        self.parent = parent
        self.attrs = attrs

    def to_dict(self) -> dict:
        d = {"kind": "span", "name": self.name,
             "ts": round(self.t_start, 6), "dur_s": round(self.dur_s, 6),
             "depth": self.depth, "parent": self.parent}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _NullSpan:
    """Shared no-op context manager for disabled tracers (re-entrant)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._tracer._push(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        self._tracer._pop(self, dur)
        return False


class _Stack(threading.local):
    def __init__(self):
        self.names: List[str] = []


class Tracer:
    """Collects :class:`SpanEvent` records; see module docstring.

    ``sink`` is any object with a ``write(dict)`` method (duck-typed to
    :class:`repro.obs.export.JsonlWriter`); writes happen at span exit on
    the span's thread.
    """

    def __init__(self, enabled: bool = True, sink=None,
                 max_events: int = 100_000):
        self.enabled = enabled
        self.sink = sink
        self.events: Deque[SpanEvent] = deque(maxlen=max_events)
        self._epoch = time.perf_counter()
        self._stack = _Stack()
        self._lock = threading.Lock()
        self._totals: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs or None)

    # -- internals used by _Span --------------------------------------------
    def _push(self, name: str) -> None:
        self._stack.names.append(name)

    def _pop(self, span: _Span, dur: float) -> None:
        stack = self._stack.names
        stack.pop()
        ev = SpanEvent(span.name, time.perf_counter() - self._epoch - dur,
                       dur, len(stack), stack[-1] if stack else None,
                       span.attrs)
        with self._lock:
            self.events.append(ev)
            self._totals[ev.name] = self._totals.get(ev.name, 0.0) + dur
            self._counts[ev.name] = self._counts.get(ev.name, 0) + 1
        if self.sink is not None:
            self.sink.write(ev.to_dict())

    # -- read side ----------------------------------------------------------
    def totals(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name aggregate: total seconds + completed-span count."""
        with self._lock:
            return {name: {"total_s": t, "count": self._counts[name]}
                    for name, t in sorted(self._totals.items())}


#: Process-wide disabled tracer.
NULL_TRACER = Tracer(enabled=False)
