"""Telemetry exporters: JSONL event log + snapshot merging + validation.

Three consumers, three forms:

* **JSONL event log** (:class:`JsonlWriter`) — an append-only stream of
  one-line JSON events (spans from :mod:`repro.obs.trace`, per-request
  lifecycle records from :mod:`repro.runtime.scheduler`, a final metrics
  snapshot).  The CI ``telemetry-smoke`` step validates this file with
  ``python -m repro.obs.export --validate PATH``.
* **End-of-run snapshot dict** — ``Engine.metrics_snapshot()`` returns a
  nested dict; :func:`latency_columns` / :func:`sparsity_columns` distill
  it into the flat columns ``benchmarks/run.py --json`` rows carry
  (``BENCH_serve.json`` schema v2).
* **Live polling** — the same snapshot dict, callable mid-run.

Event schema (one object per line; extra keys are allowed, types of the
required keys are not negotiable):

  kind="span":     name:str ts:num dur_s:num>=0 depth:int>=0
                   parent:str|null [attrs:dict]
  kind="request":  uid:int  t_enqueue:num t_admit:num t_first_token:num
                   t_finish:num n_tokens:int>=0 queue_wait_s:num>=0
                   ttft_s:num>=0 [itl_mean_s:num] [itl_max_s:num]
  kind="snapshot": metrics:dict
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["JsonlWriter", "validate_event", "validate_jsonl",
           "latency_columns", "sparsity_columns"]

SCHEMA_VERSION = 2

_NUM = (int, float)

#: kind -> {key: (types, extra predicate or None)}
_REQUIRED = {
    "span": {
        "name": (str, None),
        "ts": (_NUM, None),
        "dur_s": (_NUM, lambda v: v >= 0),
        "depth": (int, lambda v: v >= 0),
        "parent": ((str, type(None)), None),
    },
    "request": {
        "uid": (int, None),
        "t_enqueue": (_NUM, None),
        "t_admit": (_NUM, None),
        "t_first_token": (_NUM, None),
        "t_finish": (_NUM, None),
        "n_tokens": (int, lambda v: v >= 0),
        "queue_wait_s": (_NUM, lambda v: v >= 0),
        "ttft_s": (_NUM, lambda v: v >= 0),
    },
    "snapshot": {
        "metrics": (dict, None),
    },
}


class JsonlWriter:
    """Thread-safe append-only JSON-lines sink (duck-typed as the tracer/
    scheduler ``sink``: one ``write(dict)`` per event)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def write(self, event: Dict) -> None:
        line = json.dumps(event, sort_keys=True, default=str)
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()
                self._f.close()
                self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def validate_event(event: Dict) -> List[str]:
    """Schema problems of one event dict ([] = valid)."""
    if not isinstance(event, dict):
        return [f"event is {type(event).__name__}, not object"]
    kind = event.get("kind")
    if kind not in _REQUIRED:
        return [f"unknown kind {kind!r} (expected one of "
                f"{sorted(_REQUIRED)})"]
    problems = []
    for key, (types, pred) in _REQUIRED[kind].items():
        if key not in event:
            problems.append(f"{kind}: missing required key {key!r}")
            continue
        v = event[key]
        if isinstance(v, bool) or not isinstance(v, types):
            problems.append(f"{kind}.{key}: {type(v).__name__} is not "
                            "an accepted type")
        elif pred is not None and not pred(v):
            problems.append(f"{kind}.{key}: value {v!r} out of range")
    if kind == "span" and "attrs" in event \
            and not isinstance(event["attrs"], dict):
        problems.append("span.attrs must be an object")
    return problems


def validate_jsonl(path: str, max_errors: int = 20
                   ) -> Tuple[int, List[str]]:
    """Validate every line of a JSONL telemetry file.

    Returns ``(n_events, errors)``; an empty error list means the file
    parses and every event passes :func:`validate_event`.
    """
    n, errors = 0, []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {lineno}: not JSON ({e.msg})")
            else:
                errors.extend(f"line {lineno}: {p}"
                              for p in validate_event(event))
            if len(errors) >= max_errors:
                errors.append("... (truncated)")
                break
    return n, errors


# ---------------------------------------------------------------------------
# Snapshot -> flat bench columns (BENCH_serve.json schema v2)
# ---------------------------------------------------------------------------

def _ms(v: Optional[float]) -> Optional[float]:
    return None if v is None else round(v * 1e3, 2)


def latency_columns(snapshot: Dict) -> Dict:
    """TTFT / inter-token latency percentile columns from a
    ``metrics_snapshot()`` dict (absent histograms yield no columns)."""
    cols: Dict = {}
    hists = snapshot.get("metrics", {}).get("histograms", {})
    for hist, col in (("serve.ttft_s", "ttft"), ("serve.itl_s", "itl")):
        h = hists.get(hist) or {}
        if h.get("count"):
            cols[f"{col}_p50_ms"] = _ms(h["p50"])
            cols[f"{col}_p95_ms"] = _ms(h["p95"])
            cols[f"{col}_p99_ms"] = _ms(h["p99"])
            cols[f"{col}_mean_ms"] = _ms(h["mean"])
    return cols


def sparsity_columns(snapshot: Dict) -> Dict:
    """Realized-sparsity columns: mean realized k/N and winner overlap
    across layers, plus the estimated sparse-path share of decode time."""
    cols: Dict = {}
    layers = snapshot.get("sparsity", {}).get("layers", {})
    rk = [e["realized_k_frac"] for e in layers.values()
          if "realized_k_frac" in e]
    ov = [e["winner_overlap"] for e in layers.values()
          if "winner_overlap" in e]
    if rk:
        cols["realized_k_frac"] = round(sum(rk) / len(rk), 4)
    if ov:
        cols["winner_overlap"] = round(sum(ov) / len(ov), 4)
    paths = snapshot.get("sparsity", {}).get("paths", {})
    if "sparse_flop_frac_est" in paths:
        cols["sparse_flop_frac_est"] = paths["sparse_flop_frac_est"]
    return cols


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Validate a telemetry JSONL event log.")
    ap.add_argument("--validate", metavar="PATH", required=True,
                    help="JSONL file to check against the event schema")
    ap.add_argument("--min-events", type=int, default=1,
                    help="fail if fewer events than this (default 1)")
    args = ap.parse_args(argv)
    try:
        n, errors = validate_jsonl(args.validate)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for e in errors:
        print(f"INVALID {args.validate}: {e}", file=sys.stderr)
    if not errors and n < args.min_events:
        print(f"INVALID {args.validate}: only {n} events "
              f"(need >= {args.min_events})", file=sys.stderr)
        return 1
    if errors:
        return 1
    print(f"{args.validate}: {n} events, schema OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
