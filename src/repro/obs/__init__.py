"""Runtime observability: metrics registry, span tracer, realized-sparsity
telemetry, exporters.

:class:`Telemetry` bundles the three runtime surfaces the serving and
training stacks share:

* ``registry`` — counters / gauges / latency histograms
  (:mod:`repro.obs.metrics`),
* ``tracer`` — nested wall-clock spans with optional JSONL streaming
  (:mod:`repro.obs.trace`),
* ``sparsity`` / ``dispatch`` — realized activation sparsity per layer
  and execution-path attribution (:mod:`repro.obs.sparsity`).

``Telemetry.off()`` (the default everywhere) hands out the null registry
and tracer: every instrumented call site degrades to a no-op attribute
call, and nothing extra is staged into any jit — the invariant the
disabled-mode tests and the ``repro.analysis`` CI lint pin down.

See ``src/repro/obs/README.md`` for the metrics catalogue.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .export import JsonlWriter, latency_columns, sparsity_columns
from .metrics import (DEFAULT_LATENCY_EDGES_S, NULL_REGISTRY, Counter,
                      Gauge, Histogram, Registry, RollingHistogram)
from .sparsity import DispatchStats, SparsityStats
from .trace import NULL_TRACER, Tracer

__all__ = ["Telemetry", "Registry", "Counter", "Gauge", "Histogram",
           "RollingHistogram", "Tracer", "JsonlWriter", "SparsityStats",
           "DispatchStats", "NULL_REGISTRY", "NULL_TRACER",
           "DEFAULT_LATENCY_EDGES_S", "latency_columns",
           "sparsity_columns"]


@dataclasses.dataclass
class Telemetry:
    """One run's observability bundle (engine-, trainer- or test-owned).

    ``sparsity_every`` — probe the decode batch's realized sparsity every
    N decode steps (0 disables the probed step entirely; 1 probes every
    step).  The probe is a *separate* jit returning the winner supports
    as extra outputs, so the un-probed step's staged program is
    untouched.
    """

    registry: Registry
    tracer: Tracer
    enabled: bool = True
    sparsity_every: int = 1
    sink: Optional[JsonlWriter] = None

    @classmethod
    def on(cls, jsonl_path: Optional[str] = None,
           sparsity_every: int = 1) -> "Telemetry":
        sink = JsonlWriter(jsonl_path) if jsonl_path else None
        return cls(registry=Registry(enabled=True),
                   tracer=Tracer(enabled=True, sink=sink),
                   enabled=True, sparsity_every=sparsity_every, sink=sink)

    @classmethod
    def off(cls) -> "Telemetry":
        return cls(registry=NULL_REGISTRY, tracer=NULL_TRACER,
                   enabled=False, sparsity_every=0, sink=None)

    def emit(self, event: Dict) -> None:
        """Write one non-span event (request lifecycle, final snapshot)
        to the JSONL sink, if any."""
        if self.sink is not None:
            self.sink.write(event)

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()
