"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function defines the exact semantics its kernel must
reproduce; tests sweep shapes/dtypes and ``assert_allclose`` kernel
(interpret=True) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ref_packed_matmul(x: jax.Array, packed: jax.Array,
                      route: jax.Array) -> jax.Array:
    """Decompress-and-matmul oracle.

    x: (B, D_in); packed/route: (G, P, N). Returns (B, G*N) fp32.
    """
    g, p, n = packed.shape
    idx = jnp.arange(p, dtype=jnp.int32)[None, :, None] * n + route.astype(jnp.int32)
    w = jnp.zeros((p * n, g, n), jnp.float32)
    gg = jnp.arange(g, dtype=jnp.int32)[:, None, None]
    ss = jnp.arange(n, dtype=jnp.int32)[None, None, :]
    w = w.at[idx, gg, ss].set(packed.astype(jnp.float32))
    return x.astype(jnp.float32) @ w.reshape(p * n, g * n)


def ref_grouped_cs_matmul(xg: jax.Array, packed: jax.Array) -> jax.Array:
    """Shared-route grouped-matmul oracle.

    xg: (N, B, P) — activations already statically permuted slot-major.
    packed: (N, P, G). Returns (N, B, G) fp32: out[s] = xg[s] @ packed[s].
    """
    return jnp.einsum("nbp,npg->nbg", xg.astype(jnp.float32),
                      packed.astype(jnp.float32))


def ref_topk_gather(vals: jax.Array, p_idx: jax.Array, s_off: jax.Array,
                    packed_p: jax.Array, route_p: jax.Array) -> jax.Array:
    """Sparse-sparse gather oracle.

    vals/p_idx/s_off: (B, K) — the K non-zero activations (value, partition
    index, offset-within-partition).  packed_p/route_p: (P, G, N)
    (partition-major layout).  Returns (B, G*N) fp32.
    """
    b, k = vals.shape
    p, g, n = packed_p.shape
    wrow = packed_p[p_idx]                      # (B, K, G, N)
    rrow = route_p[p_idx]                       # (B, K, G, N)
    hit = rrow == s_off[:, :, None, None].astype(rrow.dtype)
    contrib = wrow.astype(jnp.float32) * hit.astype(jnp.float32)
    y = jnp.einsum("bk,bkgs->bgs", vals.astype(jnp.float32), contrib)
    return y.reshape(b, g * n)


def ref_kwta_hist(x: jax.Array, k: int, bins: int = 256) -> jax.Array:
    """Histogram-threshold k-WTA oracle (paper Fig. 10 semantics).

    Keeps every element whose quantized bin >= the threshold bin, where the
    threshold bin is the largest bin t such that #(elements with bin >= t)
    >= k. Returns x masked (same dtype).
    """
    d = x.shape[-1]
    if k >= d:
        return x
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.where(hi > lo, (bins - 1) / (hi - lo), jnp.zeros_like(hi))
    b = jnp.clip((x - lo) * scale, 0, bins - 1).astype(jnp.int32)
    hist = jax.nn.one_hot(b, bins, dtype=jnp.int32).sum(axis=-2)
    ccount = jnp.cumsum(hist[..., ::-1], axis=-1)[..., ::-1]
    tbin = jnp.clip(jnp.sum((ccount >= k).astype(jnp.int32), axis=-1) - 1,
                    0, bins - 1)
    return x * (b >= tbin[..., None]).astype(x.dtype)


def ref_topk_support(x: jax.Array, k: int):
    """(vals, p_idx, s_off) of the K largest-|x| entries, for a given N."""
    def for_n(n: int):
        _, sel = lax.top_k(jnp.abs(x), k)
        vals = jnp.take_along_axis(x, sel, axis=-1)
        return vals, (sel // n).astype(jnp.int32), (sel % n).astype(jnp.int32)
    return for_n
