"""Jit'd public wrappers around the Pallas kernels, with custom VJPs so the
kernels are usable inside training graphs.

Forward = Pallas kernel (or the jnp fallback when ``use_pallas=False`` /
running on a non-TPU backend); backward = the sparse-cost jnp formulas from
repro.core.functional (static gathers/scatters — same N-fold savings as the
forward, see DESIGN.md §3).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import functional as F
from .grouped_cs_matmul import grouped_cs_matmul
from .kwta_hist import kwta_hist_pallas
from .packed_matmul import packed_matmul, to_partition_major
from .ref import ref_kwta_hist
from .topk_gather import topk_gather_matmul, topk_support


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# packed matmul op (decompress-in-VMEM MXU path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def packed_matmul_op(x, packed, route, interpret: bool = False):
    """y = x @ decompress(packed, route); forward via the Pallas kernel."""
    pr, rr = to_partition_major(packed, route)
    y = packed_matmul(x, pr, rr, interpret=interpret or not _on_tpu())
    return y.astype(x.dtype)


def _pm_fwd(x, packed, route, interpret):
    return packed_matmul_op(x, packed, route, interpret), (x, packed, route)


def _pm_bwd(interpret, res, dy):
    """Sparse-cost backward: gradients only on the packed support, routed
    through the same static gather/scatter as the forward (DESIGN.md §3)."""
    x, packed, route = res
    g, p, n = packed.shape
    r = g // route.shape[0]
    idx = F.route_to_gather_idx(route, n)               # (Gr, P, N)
    dyr = dy.reshape(*dy.shape[:-1], g // r, r, n)
    xg = x[..., idx]
    dpacked = jnp.einsum("...ups,...urs->urps", xg, dyr)
    dpacked = dpacked.reshape(g, p, n).astype(packed.dtype)
    contrib = jnp.einsum("urps,...urs->...ups",
                         packed.reshape(g // r, r, p, n).astype(dy.dtype), dyr)
    dx = jnp.zeros_like(x).at[..., idx].add(contrib.astype(x.dtype))
    return dx, dpacked, None


packed_matmul_op.defvjp(_pm_fwd, _pm_bwd)


# ---------------------------------------------------------------------------
# grouped (shared-route) CS matmul op
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def grouped_cs_matmul_op(xg, packed_s, interpret: bool = False):
    """out[s] = xg[s] @ packed_s[s]; (N, B, P) x (N, P, G) -> (N, B, G)."""
    y = grouped_cs_matmul(xg, packed_s, interpret=interpret or not _on_tpu())
    return y.astype(xg.dtype)


def _gm_fwd(xg, packed_s, interpret):
    return grouped_cs_matmul_op(xg, packed_s, interpret), (xg, packed_s)


def _gm_bwd(interpret, res, dy):
    xg, packed_s = res
    dxg = jnp.einsum("nbg,npg->nbp", dy, packed_s.astype(dy.dtype))
    dw = jnp.einsum("nbp,nbg->npg", xg.astype(dy.dtype), dy)
    return dxg.astype(xg.dtype), dw.astype(packed_s.dtype)


grouped_cs_matmul_op.defvjp(_gm_fwd, _gm_bwd)


# ---------------------------------------------------------------------------
# sparse-sparse topk-gather op (serving path; custom_vjp for completeness)
# ---------------------------------------------------------------------------

def topk_gather_op(x, packed, route, k: int, interpret: bool = False):
    """Sparse-sparse contraction via the Pallas kernel.

    x: (B, D_in) k-sparse; packed (G, P, N); route (G/R, P, N).
    """
    g, p, n = packed.shape
    vals, p_idx, s_off = topk_support(x, k, n)
    pr, rr = to_partition_major(packed, route)
    y = topk_gather_matmul(vals, p_idx, s_off, pr, rr,
                           interpret=interpret or not _on_tpu())
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# histogram k-WTA op (straight-through gradient on the kept support)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def kwta_hist_op(x, k: int, interpret: bool = False):
    return kwta_hist_pallas(x, k, interpret=interpret or not _on_tpu())


def _kh_fwd(x, k, interpret):
    y = kwta_hist_op(x, k, interpret)
    return y, (y != 0)


def _kh_bwd(k, interpret, mask, dy):
    return (dy * mask.astype(dy.dtype),)


kwta_hist_op.defvjp(_kh_fwd, _kh_bwd)
