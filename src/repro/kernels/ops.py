"""Jit'd public wrappers around the Pallas kernels, with custom VJPs so the
kernels are usable inside training graphs.

Forward = Pallas kernel (or the jnp fallback when ``use_pallas=False`` /
running on a non-TPU backend); backward = the sparse-cost jnp formulas from
repro.core.functional (static gathers/scatters — same N-fold savings as the
forward, see DESIGN.md §3).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import functional as F
from .grouped_cs_matmul import grouped_cs_matmul
from .kwta_hist import kwta_hist_pallas
from .packed_matmul import packed_matmul, to_partition_major
from .ref import ref_kwta_hist
from .topk_gather import topk_gather_matmul, topk_support


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# packed matmul op (decompress-in-VMEM MXU path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def packed_matmul_op(x, packed, route, interpret: bool = False):
    """y = x @ decompress(packed, route); forward via the Pallas kernel."""
    pr, rr = to_partition_major(packed, route)
    y = packed_matmul(x, pr, rr, interpret=interpret or not _on_tpu())
    return y.astype(x.dtype)


def _pm_fwd(x, packed, route, interpret):
    return packed_matmul_op(x, packed, route, interpret), (x, packed, route)


def _pm_bwd(interpret, res, dy):
    """Sparse-cost backward: gradients only on the packed support, routed
    through the same static gather/scatter as the forward (DESIGN.md §3)."""
    x, packed, route = res
    g, p, n = packed.shape
    r = g // route.shape[0]
    idx = F.route_to_gather_idx(route, n)               # (Gr, P, N)
    dyr = dy.reshape(*dy.shape[:-1], g // r, r, n)
    xg = x[..., idx]
    dpacked = jnp.einsum("...ups,...urs->urps", xg, dyr)
    dpacked = dpacked.reshape(g, p, n).astype(packed.dtype)
    contrib = jnp.einsum("urps,...urs->...ups",
                         packed.reshape(g // r, r, p, n).astype(dy.dtype), dyr)
    dx = jnp.zeros_like(x).at[..., idx].add(contrib.astype(x.dtype))
    return dx, dpacked, None


packed_matmul_op.defvjp(_pm_fwd, _pm_bwd)


# ---------------------------------------------------------------------------
# grouped (shared-route) CS matmul op
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def grouped_cs_matmul_op(xg, packed_s, interpret: bool = False):
    """out[s] = xg[s] @ packed_s[s]; (N, B, P) x (N, P, G) -> (N, B, G)."""
    y = grouped_cs_matmul(xg, packed_s, interpret=interpret or not _on_tpu())
    return y.astype(xg.dtype)


def _gm_fwd(xg, packed_s, interpret):
    return grouped_cs_matmul_op(xg, packed_s, interpret), (xg, packed_s)


def _gm_bwd(interpret, res, dy):
    xg, packed_s = res
    dxg = jnp.einsum("nbg,npg->nbp", dy, packed_s.astype(dy.dtype))
    dw = jnp.einsum("nbp,nbg->npg", xg.astype(dy.dtype), dy)
    return dxg.astype(xg.dtype), dw.astype(packed_s.dtype)


grouped_cs_matmul_op.defvjp(_gm_fwd, _gm_bwd)


# ---------------------------------------------------------------------------
# sparse-sparse topk-gather op (serving path; straight-through custom_vjp:
# gradients flow only on the selected support, mirroring _pm_bwd)
# ---------------------------------------------------------------------------

def _float0(a):
    """Zero cotangent for integer primals (JAX's float0 convention)."""
    return np.zeros(a.shape, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def topk_gather_support_op(vals, p_idx, s_off, packed, route,
                           interpret: bool = False):
    """Batched sparse-sparse contraction consuming an explicit support.

    The executor target of the sparse-activation handoff: the upstream
    k-WTA already ran the ONE Select of the layer, so this takes the
    support directly and issues a single Pallas launch for the whole
    (flattened) decode batch.

    vals/p_idx/s_off: (..., K) support (see ``F.topk_support_flat``);
    packed: (G, P, N); route: (G/R, P, N).  Returns (..., G*N) in
    ``vals.dtype``.
    """
    g, p, n = packed.shape
    lead, k = vals.shape[:-1], vals.shape[-1]
    pr, rr = to_partition_major(packed, route)
    y = topk_gather_matmul(vals.astype(jnp.float32).reshape(-1, k),
                           p_idx.reshape(-1, k), s_off.reshape(-1, k),
                           pr, rr, interpret=interpret or not _on_tpu())
    return y.reshape(*lead, g * n).astype(vals.dtype)


def _tgs_fwd(vals, p_idx, s_off, packed, route, interpret):
    y = topk_gather_support_op(vals, p_idx, s_off, packed, route, interpret)
    return y, (vals, p_idx, s_off, packed, route)


def _tgs_bwd(interpret, res, dy):
    """Sparse-cost backward on the selected support only: d_vals re-reads
    the same K packed rows as the forward; d_packed scatter-adds each
    non-zero's contribution into its partition row (same N-fold savings)."""
    vals, p_idx, s_off, packed, route = res
    g, p, n = packed.shape
    r = g // route.shape[0]
    k = vals.shape[-1]
    wrow = jnp.moveaxis(jnp.take(packed, p_idx, axis=1), 0, -2)  # (...,K,G,N)
    rrow = jnp.moveaxis(jnp.take(route, p_idx, axis=1), 0, -2)   # (...,K,Gr,N)
    hit = (rrow == s_off[..., None, None].astype(rrow.dtype))
    hit = (jnp.repeat(hit, r, axis=-2) if r > 1 else hit).astype(jnp.float32)
    dyr = dy.reshape(*dy.shape[:-1], g, n).astype(jnp.float32)
    wsel = wrow.astype(jnp.float32) * hit
    dvals = jnp.einsum("...gs,...kgs->...k", dyr, wsel).astype(vals.dtype)
    contrib = (vals.astype(jnp.float32)[..., None, None]
               * dyr[..., None, :, :] * hit)                     # (...,K,G,N)
    dpacked = jnp.zeros((g, p, n), jnp.float32).at[
        :, p_idx.reshape(-1, k), :].add(
        jnp.moveaxis(contrib.reshape(-1, k, g, n), 2, 0))
    return (dvals, _float0(p_idx), _float0(s_off),
            dpacked.astype(packed.dtype), _float0(route))


topk_gather_support_op.defvjp(_tgs_fwd, _tgs_bwd)


def topk_gather_op(x, packed, route, k: int, interpret: bool = False):
    """Sparse-sparse contraction via the Pallas kernel, Select included.

    x: (..., D_in) k-sparse; packed (G, P, N); route (G/R, P, N).
    Differentiable: d_x flows straight-through onto the selected support
    (via the take_along_axis in the Select), d_packed via the custom VJP of
    :func:`topk_gather_support_op`.
    """
    n = packed.shape[2]
    vals, p_idx, s_off = topk_support(x, k, n)
    return topk_gather_support_op(vals, p_idx, s_off, packed, route,
                                  interpret).astype(x.dtype)


# ---------------------------------------------------------------------------
# histogram k-WTA op (straight-through gradient on the kept support)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def kwta_hist_op(x, k: int, interpret: bool = False):
    return kwta_hist_pallas(x, k, interpret=interpret or not _on_tpu())


def _kh_fwd(x, k, interpret):
    y = kwta_hist_op(x, k, interpret)
    return y, (y != 0)


def _kh_bwd(k, interpret, mask, dy):
    return (dy * mask.astype(dy.dtype),)


kwta_hist_op.defvjp(_kh_fwd, _kh_bwd)
