"""Pallas TPU kernels for the complementary-sparsity compute hot-spots.

Kernels (each: <name>.py with pl.pallas_call + BlockSpec VMEM tiling,
``ops.py`` jit'd/differentiable wrappers, ``ref.py`` pure-jnp oracles):

* ``packed_matmul``     — matmul with in-VMEM CS decompression (MXU path).
* ``grouped_cs_matmul`` — shared-route grouped matmul (N× fewer MXU FLOPs).
* ``topk_gather``       — batched sparse-sparse contraction (K non-zeros
  only; (nG, B) grid keeps the packed tile VMEM-resident across the whole
  decode batch — one launch per layer per step).
* ``kwta_hist``         — histogram-threshold global k-WTA (paper Fig. 10).

Layer code does not call these directly: ``packed_linear_apply`` routes
through the executor flag ``SparsityConfig.use_pallas`` ('auto' = Pallas
on TPU only, 'force' = everywhere with interpret fallback off-TPU, 'off' =
pure jnp) — see :func:`repro.core.api.choose_executor`.  The serving
entrypoint exposes it as ``Engine(..., use_pallas=...)`` /
``--use-pallas``.
"""

from .block_validation import (check_block_shape, estimate_vmem_bytes,
                               validate_block, validate_blocks, vmem_budget)
from .grouped_cs_matmul import (grouped_cs_matmul, interleave_out,
                                permute_activations, slot_major_packed)
from .kwta_hist import kwta_hist_pallas
from .ops import (grouped_cs_matmul_op, kwta_hist_op, packed_matmul_op,
                  topk_gather_op, topk_gather_support_op)
from .packed_matmul import packed_matmul, to_partition_major
from .registry import KernelCase, kernel_cases
from .topk_gather import topk_gather_matmul, topk_support

__all__ = [
    "grouped_cs_matmul", "interleave_out", "permute_activations",
    "slot_major_packed", "kwta_hist_pallas", "grouped_cs_matmul_op",
    "kwta_hist_op", "packed_matmul_op", "topk_gather_op",
    "topk_gather_support_op", "packed_matmul", "to_partition_major",
    "topk_gather_matmul", "topk_support", "KernelCase", "kernel_cases",
    "check_block_shape", "estimate_vmem_bytes", "validate_block",
    "validate_blocks", "vmem_budget",
]
