"""Pallas TPU kernel: sparse-sparse CS contraction (paper §3.2 / Fig. 8).

Implements the five-step sparse-sparse pipeline's hot loop: for each of the
K non-zero activations, fetch the corresponding packed weight row (the
paper's 'K-ported weight memory' becomes K sequential VMEM dynamic slices —
on TPU, parallelism comes from the (G, N) lane dimensions of each fetched
row instead of from memory ports), mask by Kernel-ID match (route ==
offset), scale by the activation value, and accumulate.

FLOPs: 2·B·K·D_out — the multiplicative sparse-sparse saving
(D_in/K from activations × N from weights on the memory side).

Layouts:
  vals   (B, K)       activation values (f32)
  p_idx  (B, K) int32 partition index of each non-zero
  s_off  (B, K) int32 offset-within-partition of each non-zero
  packed (P, G, N)    partition-major packed weights
  route  (P, G, N)    int8
  out    (B, G*N)     f32

Grid: (nG, B) — batch innermost.  Each step loops over K with a fori_loop
of dynamic row loads; the weight tile (P, block_g, N) stays VMEM-resident
across the K loop AND across the whole decode batch: with B as the fastest
grid dimension the packed/route index maps are constant while b sweeps, so
Pallas' revisit caching skips the re-fetch and one launch serves every
decode slot (the batched-decode regime of arXiv 2311.07625 — weight reads
amortize over B, which is where weight × activation sparsity multiply).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .block_validation import validate_block


def _topk_gather_kernel(vals_ref, pidx_ref, soff_ref, packed_ref, route_ref, o_ref,
            *, k_nnz: int):
    vals = vals_ref[0]            # (K,)
    pidx = pidx_ref[0]            # (K,)
    soff = soff_ref[0]            # (K,)
    bg, n = packed_ref.shape[1], packed_ref.shape[2]

    def body(j, acc):
        p = pidx[j]
        w = packed_ref[pl.ds(p, 1), :, :][0]
        r = route_ref[pl.ds(p, 1), :, :][0]
        hit = r == soff[j].astype(r.dtype)
        return acc + jnp.where(hit, w.astype(jnp.float32), 0.0) * vals[j]

    acc = lax.fori_loop(0, k_nnz, body, jnp.zeros((bg, n), jnp.float32))
    o_ref[0] = acc.reshape(bg * n)


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def topk_gather_matmul(vals: jax.Array, p_idx: jax.Array, s_off: jax.Array,
                       packed_p: jax.Array, route_p: jax.Array,
                       block_g: int = 0, interpret: bool = False) -> jax.Array:
    """Sparse-sparse contraction of K non-zeros against packed weights.

    Returns (B, G*N) float32. See module docstring for layouts.
    """
    b, k_nnz = vals.shape
    p, g, n = packed_p.shape
    block_g = block_g or g
    if k_nnz < 1:
        raise ValueError(f"k_nnz={k_nnz} must be >= 1 (at least one "
                         "non-zero per row)")
    # Explicit-block convention: an oversized block_g is the caller's error,
    # not something to clamp away (shared validator, clamp=False).
    block_g = validate_block("block_g", block_g, g, "G", clamp=False)
    # Grid order (nG, B): batch innermost so the packed/route tiles (index
    # maps ignore ib) are revisited — fetched once per group tile, resident
    # in VMEM for the whole decode batch.
    return pl.pallas_call(
        functools.partial(_topk_gather_kernel, k_nnz=k_nnz),
        grid=(g // block_g, b),
        in_specs=[
            pl.BlockSpec((1, k_nnz), lambda ig, ib: (ib, 0)),
            pl.BlockSpec((1, k_nnz), lambda ig, ib: (ib, 0)),
            pl.BlockSpec((1, k_nnz), lambda ig, ib: (ib, 0)),
            pl.BlockSpec((p, block_g, n), lambda ig, ib: (0, ig, 0)),
            pl.BlockSpec((p, block_g, n), lambda ig, ib: (0, ig, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_g * n), lambda ig, ib: (ib, ig)),
        out_shape=jax.ShapeDtypeStruct((b, g * n), jnp.float32),
        interpret=interpret,
    )(vals, p_idx.astype(jnp.int32), s_off.astype(jnp.int32),
      packed_p, route_p)


def topk_support(x: jax.Array, k: int, n: int):
    """Select step (paper's k-WTA + index extraction): the K largest-|x|
    positions as (vals, p_idx, s_off). Exact for any k-sparse x."""
    from repro.core.instrument import counted_top_k
    _, sel = counted_top_k(jnp.abs(x), k)
    vals = jnp.take_along_axis(x, sel, axis=-1)
    return (vals.astype(jnp.float32), (sel // n).astype(jnp.int32),
            (sel % n).astype(jnp.int32))
