"""Pallas TPU kernel: shared-route grouped CS matmul (the MXU-native
realization of the paper's Multiply→Route→Sum with true N-fold FLOP
reduction).

With route sharing (DESIGN.md §3), all G output groups share one
permutation per partition, so the runtime routing collapses to a single
*static* activation permutation (applied outside, free at trace time) and
the remaining compute is N independent (B, P) @ (P, G) matmuls — one per
pack slot.  Total MXU FLOPs = 2·B·P·G·N = 2·B·D_in·D_out / N: the paper's
N× MAC reduction executed at full MXU rate.

Layouts:
  xg     (N, B, P)  slot-major permuted activations
  packed (N, P, G)
  out    (N, B, G)  f32 (wrapper reinterleaves to (B, D_out))

Grid: (s, nb, ng, nk), k innermost for accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .block_validation import validate_blocks


def _grouped_cs_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]      # (bb, bp)
    w = w_ref[0]      # (bp, bg)
    o_ref[0] += jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_p", "block_g",
                                             "interpret"))
def grouped_cs_matmul(xg: jax.Array, packed: jax.Array,
                      block_b: int = 128, block_p: int = 256,
                      block_g: int = 256, interpret: bool = False) -> jax.Array:
    """out[s] = xg[s] @ packed[s] for each pack slot s.

    Args:
      xg: (N, B, P) statically-permuted activations.
      packed: (N, P, G).
    Returns: (N, B, G) float32.
    """
    n, b, p = xg.shape
    n2, p2, g = packed.shape
    if (n2, p2) != (n, p):
        raise ValueError(f"xg {xg.shape} vs packed {packed.shape}")
    # Defaulted-block convention: clamp to the dim, then require exact
    # divisibility (shared validator — uniform message across kernels).
    block_b, block_p, block_g = validate_blocks((
        ("block_b", block_b, b, "B"),
        ("block_p", block_p, p, "P"),
        ("block_g", block_g, g, "G")))
    grid = (n, b // block_b, g // block_g, p // block_p)
    return pl.pallas_call(
        _grouped_cs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, block_p),
                         lambda s, ib, ig, ik: (s, ib, ik)),
            pl.BlockSpec((1, block_p, block_g),
                         lambda s, ib, ig, ik: (s, ik, ig)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_g),
                               lambda s, ib, ig, ik: (s, ib, ig)),
        out_shape=jax.ShapeDtypeStruct((n, b, g), jnp.float32),
        interpret=interpret,
    )(xg, packed)


def permute_activations(x: jax.Array, route_shared) -> jax.Array:
    """Apply the shared static route to activations: (B, D_in) -> (N, B, P).

    ``route_shared`` is the (1, P, N) (or (P, N)) shared permutation — a
    *static* numpy-known array, so this gather lowers to a compile-time
    permutation (no runtime crossbar; DESIGN.md §2).
    """
    import numpy as np
    r = np.asarray(route_shared)
    r = r.reshape(r.shape[-2], r.shape[-1])          # (P, N)
    p, n = r.shape
    idx = (np.arange(p)[:, None] * n + r).astype(np.int32)  # (P, N)
    xg = x[..., idx]                                  # (B, P, N)
    return jnp.moveaxis(xg, -1, 0)                    # (N, B, P)


def slot_major_packed(packed: jax.Array) -> jax.Array:
    """core (G, P, N) -> kernel (N, P, G)."""
    return packed.transpose(2, 1, 0)


def interleave_out(y: jax.Array) -> jax.Array:
    """kernel (N, B, G) -> (B, G*N) with outputs ordered [g*N + s]."""
    n, b, g = y.shape
    return y.transpose(1, 2, 0).reshape(b, g * n)
