"""Shared Pallas block-size validation.

Every ``pallas_call`` wrapper in this package validates its block sizes
here so that (a) the clamp-then-check order is identical everywhere —
defaulted block sizes are first clamped to the array dim, *then* checked
for divisibility — and (b) error messages are uniform
(``block_x=B must divide X=D`` / ``block_x=B exceeds X=D``), so tests and
the static analyzer (:mod:`repro.analysis`) can match them.

The same constants and pure helpers back the analyzer's Pallas resource
rule: :func:`check_block_shape` re-checks divisibility on block shapes
recovered from a staged jaxpr, and :func:`estimate_vmem_bytes` estimates
the per-grid-step VMEM footprint against :data:`VMEM_BUDGET_BYTES`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Per-backend VMEM budget for one grid step's resident blocks, in bytes.
#: TPU cores have ~16 MiB of VMEM (see the Pallas TPU guide); the compiler
#: needs headroom for scratch/double-buffering, so the lint budget is half.
#: Non-TPU backends interpret the kernels, but are checked against the TPU
#: budget anyway — that is the point of linting on CPU in CI.
VMEM_BYTES = {"tpu": 16 * 2 ** 20}
VMEM_BUDGET_BYTES = {k: v // 2 for k, v in VMEM_BYTES.items()}
DEFAULT_VMEM_BUDGET = VMEM_BUDGET_BYTES["tpu"]


def validate_block(name: str, block: int, dim: int, dim_name: str,
                   clamp: bool = True) -> int:
    """Validate (and optionally clamp) one block size against its dim.

    With ``clamp=True`` (the defaulted-block-size convention) the block is
    first reduced to ``min(block, dim)``; with ``clamp=False`` an oversized
    block is an error (the explicit-block-size convention).  Either way the
    resulting block must divide the dim exactly — Pallas would silently pad
    otherwise, and padded tiles break the routed/packed layouts.

    Returns the validated (possibly clamped) block size.
    """
    if block < 1:
        raise ValueError(f"{name}={block} must be >= 1")
    if block > dim:
        if not clamp:
            raise ValueError(f"{name}={block} exceeds {dim_name}={dim}")
        block = dim
    if dim % block:
        raise ValueError(f"{name}={block} must divide {dim_name}={dim}")
    return block


def validate_blocks(spec: Sequence[Tuple[str, int, int, str]],
                    clamp: bool = True) -> Tuple[int, ...]:
    """Validate several ``(name, block, dim, dim_name)`` entries at once."""
    return tuple(validate_block(name, block, dim, dim_name, clamp=clamp)
                 for name, block, dim, dim_name in spec)


# ---------------------------------------------------------------------------
# Pure checkers shared with the static analyzer (no raising — they return
# problem strings so the analyzer can turn them into findings).
# ---------------------------------------------------------------------------

def check_block_shape(block_shape: Sequence, array_shape: Sequence[int],
                      ) -> List[str]:
    """Divisibility problems of one BlockSpec against its array shape.

    Non-integer block entries (squeezed/mapped grid dims) are skipped.
    """
    problems: List[str] = []
    if len(block_shape) != len(array_shape):
        return [f"block rank {len(block_shape)} != array rank "
                f"{len(array_shape)}"]
    for axis, (b, d) in enumerate(zip(block_shape, array_shape)):
        if not isinstance(b, (int, np.integer)):
            continue
        if b > d:
            problems.append(f"block dim {int(b)} exceeds array dim {d} "
                            f"(axis {axis})")
        elif d % b:
            problems.append(f"block dim {int(b)} does not divide array dim "
                            f"{d} (axis {axis})")
    return problems


def block_bytes(block_shape: Sequence, dtype) -> int:
    """Bytes of one block (non-integer/mapped entries count as 1)."""
    n = 1
    for b in block_shape:
        if isinstance(b, (int, np.integer)):
            n *= int(b)
    return n * np.dtype(dtype).itemsize


def estimate_vmem_bytes(blocks: Sequence[Tuple[Sequence, object]]) -> int:
    """Per-grid-step VMEM estimate: sum of (block_shape, dtype) buffers.

    One buffer per kernel operand/output; double-buffering and scratch are
    the compiler's business — the budget constant leaves headroom for them.
    """
    return sum(block_bytes(shape, dt) for shape, dt in blocks)


def vmem_budget(backend: Optional[str] = None) -> int:
    """VMEM lint budget for ``backend`` (default: the TPU budget)."""
    return VMEM_BUDGET_BYTES.get(backend or "tpu", DEFAULT_VMEM_BUDGET)
