"""Registry of the package's Pallas kernels for the kernel-body verifier.

Each entry declares how to *stage* one shipped kernel wrapper at a given
shape configuration (abstract tracing only — nothing runs), plus the
value-range **provenance** of its index-carrying operands.  The verifier
(:mod:`repro.analysis.kernel_rules`) sweeps every case and proves the
body's Ref accesses in-bounds, its cross-grid-step writes race-free, its
padded loads masked, and its scratch within the VMEM budget.

The provenance declarations are the verifier's trust root: they encode
facts about how the *wrappers'* callers construct the operands, which
the kernel body alone cannot know.  Each registration carries a comment
saying why the range holds; if a caller ever violates it, the proof is
vacuous — keep the declarations next to the code that guarantees them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One (kernel, shape config) staging recipe.

    ``trace()`` returns the ClosedJaxpr of the wrapper applied to
    abstract operands at this configuration."""

    kernel: str          # wrapper name: topk_gather, grouped_cs_matmul, ...
    label: str           # e.g. "topk_gather[b4 k16 p32 g8 n4 bg8]"
    trace: Callable[[], object]

    def __repr__(self):  # pragma: no cover - debugging nicety
        return f"KernelCase({self.label})"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _trace(fn, *args, **static):
    import functools
    return jax.make_jaxpr(functools.partial(fn, **static))(*args)


# ---------------------------------------------------------------------------
# Shape sweeps.  Each tuple is one configuration the CI sweep must prove
# clean; they bracket the regimes the serving/train paths actually use
# (single-tile grids, multi-k accumulation grids, batched decode grids).
# ---------------------------------------------------------------------------

#: topk_gather_matmul: (b, k_nnz, p, g, n, block_g)
TOPK_GATHER_SWEEP = (
    (4, 16, 32, 8, 4, 8),       # decode batch, single group tile
    (8, 32, 64, 16, 4, 8),      # grid (2, 8): group-tiled, batch innermost
    (2, 8, 16, 4, 4, 2),        # tiny shapes, block_g < g
)

#: grouped_cs_matmul: (n, b, p, g, block_b, block_p, block_g)
GROUPED_CS_SWEEP = (
    (4, 8, 16, 8, 128, 256, 256),    # defaults clamp to dims: grid (4,1,1,1)
    (4, 16, 64, 32, 8, 16, 16),      # multi-k grid: nk = 4 accumulation steps
    (2, 128, 256, 128, 64, 64, 64),  # serving-scale tiles, nk = 4
)

#: packed_matmul: (b, p, g, n, block_b, block_p, block_g)
PACKED_MATMUL_SWEEP = (
    (8, 8, 8, 4, 128, 64, 64),       # defaults clamp: single grid step
    (16, 32, 32, 4, 8, 8, 16),       # nk = 4 accumulation steps
    (128, 64, 64, 8, 64, 32, 32),    # serving-scale, nk = 2
)

#: kwta_hist_pallas: (b, d, k, block_b)
KWTA_HIST_SWEEP = (
    (8, 64, 8, 8),
    (16, 128, 16, 4),       # batch-tiled grid (4,)
)


def kernel_cases() -> List[KernelCase]:
    """Every shipped kernel × shape configuration, as staging recipes."""
    from .grouped_cs_matmul import grouped_cs_matmul
    from .kwta_hist import kwta_hist_pallas
    from .packed_matmul import packed_matmul
    from .topk_gather import topk_gather_matmul

    cases: List[KernelCase] = []

    for b, k, p, g, n, bg in TOPK_GATHER_SWEEP:
        cases.append(KernelCase(
            "topk_gather",
            f"topk_gather[b{b} k{k} p{p} g{g} n{n} bg{bg}]",
            lambda b=b, k=k, p=p, g=g, n=n, bg=bg: _trace(
                topk_gather_matmul,
                _sds((b, k), jnp.float32), _sds((b, k), jnp.int32),
                _sds((b, k), jnp.int32), _sds((p, g, n), jnp.float32),
                _sds((p, g, n), jnp.int8), block_g=bg)))

    for n, b, p, g, bb, bp, bg in GROUPED_CS_SWEEP:
        cases.append(KernelCase(
            "grouped_cs_matmul",
            f"grouped_cs_matmul[n{n} b{b} p{p} g{g} bb{bb} bp{bp} bg{bg}]",
            lambda n=n, b=b, p=p, g=g, bb=bb, bp=bp, bg=bg: _trace(
                grouped_cs_matmul,
                _sds((n, b, p), jnp.float32), _sds((n, p, g), jnp.float32),
                block_b=bb, block_p=bp, block_g=bg)))

    for b, p, g, n, bb, bp, bg in PACKED_MATMUL_SWEEP:
        cases.append(KernelCase(
            "packed_matmul",
            f"packed_matmul[b{b} p{p} g{g} n{n} bb{bb} bp{bp} bg{bg}]",
            lambda b=b, p=p, g=g, n=n, bb=bb, bp=bp, bg=bg: _trace(
                packed_matmul,
                _sds((b, p * n), jnp.float32), _sds((p, g, n), jnp.float32),
                _sds((p, g, n), jnp.int8),
                block_b=bb, block_p=bp, block_g=bg)))

    for b, d, k, bb in KWTA_HIST_SWEEP:
        cases.append(KernelCase(
            "kwta_hist",
            f"kwta_hist[b{b} d{d} k{k} bb{bb}]",
            lambda b=b, d=d, k=k, bb=bb: _trace(
                kwta_hist_pallas, _sds((b, d), jnp.float32),
                k=k, block_b=bb)))

    return cases


# ---------------------------------------------------------------------------
# Value-range provenance (trust root — see module docstring).
# ---------------------------------------------------------------------------

_provenance_registered = False


def ensure_provenance() -> None:
    """Idempotently register the kernels' value-range declarations.

    Called by the verifier on first use (not at import time — the
    registry and the verifier import each other's packages, so eager
    registration would be a circular import)."""
    global _provenance_registered
    if _provenance_registered:
        return
    _provenance_registered = True

    from repro.analysis.intervals import Interval
    from repro.analysis.kernel_rules import register_value_ranges

    def topk_gather_ranges(refs):
        # topk_support computes p_idx = sel // n and s_off = sel % n from
        # counted_top_k over the flat [0, P*N) activation index space, so
        # p_idx ∈ [0, P) and s_off ∈ [0, N) by construction.  The packed
        # operand (position 3) is block-resident along its full partition
        # dim, so P/N are read off its block shape.
        packed = refs[3]
        p, n = packed.block_shape[0], packed.block_shape[2]
        return {1: Interval(0, p - 1),     # pidx_ref values
                2: Interval(0, n - 1)}     # soff_ref values

    register_value_ranges("_topk_gather_kernel", topk_gather_ranges)
    # grouped_cs / packed_matmul / kwta_hist index only with program_id
    # affine forms and static slices — no declared ranges needed.
