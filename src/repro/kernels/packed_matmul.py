"""Pallas TPU kernel: matmul with inline complementary-sparse decompression.

The flagship CS kernel (DESIGN.md §4).  Weights live in HBM in the packed
form (1/N of dense bytes, + int8 routes); each grid step DMAs one packed
tile into VMEM, expands it to a dense (block_k, block_o) tile *in VMEM*
(VPU: one select per pack-slot), and feeds the MXU.  The dense weight never
exists in HBM — this is the TPU analog of the paper's "sparse weights that
are almost indistinguishable from dense matrices".

Memory roofline effect: weight HBM traffic per step drops from
block_k*block_o*2 bytes to block_k*block_o*(2 + 1)/N bytes (bf16 weight +
int8 route), i.e. ~N/1.5x less. Compute is dense-rate MXU.

Layouts (chosen so tiles are contiguous):
  x        (B, D_in)               bf16/f32
  packed_r (P, G, N) = transpose of core's (G, P, N)   (partition-major)
  route_r  (P, G, N) int8
  out      (B, D_out = G*N)        f32

Grid: (nb, no, nk) — k innermost for accumulation; blocks:
  x tile       (block_b, block_p * N)
  packed tile  (block_p, block_g, N)
  out tile     (block_b, block_g * N)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .block_validation import validate_blocks


def _packed_matmul_kernel(x_ref, packed_ref, route_ref, o_ref,
                          *, n: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pr = packed_ref[...]            # (bp, bg, N)
    rr = route_ref[...]             # (bp, bg, N) int8
    bp, bg, _ = pr.shape
    # Expand to dense (bp*N, bg*N): dense[p*N + i, g*N + s] =
    #   packed[p, g, s] * (route[p, g, s] == i).
    # Static unroll over the N offsets; each slice is a masked copy (VPU).
    rows = [jnp.where(rr == jnp.int8(i), pr, jnp.zeros_like(pr))
            for i in range(n)]
    dense = jnp.stack(rows, axis=1)             # (bp, N_i, bg, N_s)
    dense = dense.reshape(bp * n, bg * n)       # row-major collapse
    x = x_ref[...]                              # (bb, bp*N)
    acc = jnp.dot(x.astype(jnp.float32), dense.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_b", "block_p", "block_g",
                                             "interpret"))
def packed_matmul(x: jax.Array, packed_r: jax.Array, route_r: jax.Array,
                  block_b: int = 128, block_p: int = 64, block_g: int = 64,
                  interpret: bool = False) -> jax.Array:
    """Compute x @ decompress(packed) with in-VMEM decompression.

    Args:
      x: (B, D_in).
      packed_r / route_r: (P, G, N) partition-major packed weights / routes.
    Returns:
      (B, G*N) float32.
    """
    b, d_in = x.shape
    p, g, n = packed_r.shape
    if p * n != d_in:
        raise ValueError(f"x d_in {d_in} != P*N {p * n}")
    block_b, block_p, block_g = validate_blocks((
        ("block_b", block_b, b, "B"),
        ("block_p", block_p, p, "P"),
        ("block_g", block_g, g, "G")))
    nb, no, nk = b // block_b, g // block_g, p // block_p
    return pl.pallas_call(
        functools.partial(_packed_matmul_kernel, n=n, nk=nk),
        grid=(nb, no, nk),
        in_specs=[
            pl.BlockSpec((block_b, block_p * n), lambda ib, io, ik: (ib, ik)),
            pl.BlockSpec((block_p, block_g, n), lambda ib, io, ik: (ik, io, 0)),
            pl.BlockSpec((block_p, block_g, n), lambda ib, io, ik: (ik, io, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_g * n),
                               lambda ib, io, ik: (ib, io)),
        out_shape=jax.ShapeDtypeStruct((b, g * n), jnp.float32),
        interpret=interpret,
    )(x, packed_r, route_r)


def to_partition_major(packed: jax.Array, route: jax.Array):
    """Convert core's (G, P, N) layout (route possibly route-shared
    (G/R, P, N)) to this kernel's (P, G, N)."""
    g = packed.shape[0]
    gr = route.shape[0]
    if gr != g:
        route = jnp.repeat(route, g // gr, axis=0)
    return packed.transpose(1, 0, 2), route.transpose(1, 0, 2)
