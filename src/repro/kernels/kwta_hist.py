"""Pallas TPU kernel: histogram-threshold global k-WTA (paper §3.3.3,
Fig. 10).

The FPGA builds M parallel histograms by scattering into count memories and
then walks the merged histogram from the top bin down until the cumulative
count reaches K.  A TPU VPU has no scatter, so we adapt the insight
("threshold search over a value histogram is cheaper than a sort") with a
**two-pass radix-16 histogram**: each pass counts 16 bins with vectorized
compares (16 reductions over the row), giving the exact 256-bin threshold in
2×16 row sweeps — O(32·D) work instead of O(D·log D) sorting, and fully
vectorized over both the batch sublanes and the D lanes.

Semantics match ``ref.ref_kwta_hist``: keep every element whose 256-level
quantized value is >= the threshold bin (>= K survivors; exact K when the
threshold bin holds a single element).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .block_validation import validate_block

_BINS = 256
_RADIX = 16


def _count_ge(q, lo_bin, width, base_mask):
    """counts[b, t] = #(elements with q in [lo_bin + t*width, ...)) for
    t in [0, 16), restricted to base_mask."""
    counts = []
    for t in range(_RADIX):
        lo = lo_bin + t * width
        sel = jnp.logical_and(base_mask, q >= lo) if width != 1 else \
            jnp.logical_and(base_mask, q == lo)
        counts.append(jnp.sum(sel.astype(jnp.int32), axis=-1))
    return jnp.stack(counts, axis=-1)  # (B, 16)


def _kwta_hist_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)            # (bb, D)
    d = x.shape[-1]
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.where(hi > lo, (_BINS - 1) / (hi - lo), jnp.zeros_like(hi))
    q = jnp.clip((x - lo) * scale, 0, _BINS - 1).astype(jnp.int32)

    # Pass 1: coarse bins of width 16. tail[t] = #(q >= 16 t). The threshold
    # coarse bin is the largest t with tail >= k.
    ones = jnp.ones(q.shape, jnp.bool_)
    tail_c = _count_ge(q, 0, _RADIX, ones)        # (bb, 16) tail counts
    ok_c = (tail_c >= k).astype(jnp.int32)
    tc = jnp.maximum(jnp.sum(ok_c, axis=-1) - 1, 0)   # (bb,)

    # Pass 2: fine bins within coarse bin tc: tail_f[u] = #(q >= 16 tc + u).
    base = 16 * tc[:, None]
    tail_f = []
    for u in range(_RADIX):
        tail_f.append(jnp.sum((q >= base + u).astype(jnp.int32), axis=-1))
    tail_f = jnp.stack(tail_f, axis=-1)           # (bb, 16)
    ok_f = (tail_f >= k).astype(jnp.int32)
    uf = jnp.maximum(jnp.sum(ok_f, axis=-1) - 1, 0)
    tbin = 16 * tc + uf                           # (bb,) threshold bin

    keep = q >= tbin[:, None]
    o_ref[...] = jnp.where(keep, x_ref[...], jnp.zeros_like(x_ref))


@functools.partial(jax.jit, static_argnames=("k", "block_b", "interpret"))
def kwta_hist_pallas(x: jax.Array, k: int, block_b: int = 8,
                     interpret: bool = False) -> jax.Array:
    """Histogram k-WTA over the last axis of (B, D)."""
    b, d = x.shape
    block_b = validate_block("block_b", block_b, b, "B")
    return pl.pallas_call(
        functools.partial(_kwta_hist_kernel, k=k),
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((block_b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=interpret,
    )(x)
