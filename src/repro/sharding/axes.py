"""Rule tables: logical axis -> mesh axes, per (mesh, workload kind).

Parallelism map (DESIGN.md §6):
  DP   : "batch"  -> ("pod", "data")      (pod axis folds into DP)
  TP   : "heads" / "mlp" / "vocab" / "kv" -> "model"
  EP   : "experts" -> "model"
  SP   : "kvseq" (KV-cache sequence) -> "model" for decode; for batch=1
         long-context also "data" — exact sharded softmax is handled by
         GSPMD's reductions.
ZeRO-1: optimizer moments additionally shard over the DP axes (see
repro/optim/adamw.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from jax.sharding import Mesh

from .context import MeshAxes, Rules


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.shape else None


def make_rules(mesh: Mesh, kind: str = "train") -> Rules:
    """Rule table for a workload kind: train | prefill | decode | decode_long.

    ``decode_long`` (batch too small to shard) moves the DP axes onto the
    KV-cache sequence dimension — sequence parallelism for the 500k-token
    cache.
    """
    dp: MeshAxes = dp_axes(mesh)
    tp = tp_axis(mesh)
    table: Dict[str, MeshAxes] = {
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": tp,
        "kv": tp,
        "mlp": tp,
        "vocab": tp,
        "experts": tp,
        "kvseq": None,
    }
    if kind in ("decode", "serve"):
        table["kvseq"] = tp  # shard the 32k cache over model
    elif kind == "decode_long":
        table["batch"] = None
        table["kvseq"] = tuple(list(dp) + ([tp] if tp else []))
        table["seq"] = None
    elif kind == "prefill":
        # sequence-parallel the activations across DP if batch is tiny;
        # handled by the divisibility fallback on "batch".
        pass
    return Rules(mesh=mesh, table=table)
