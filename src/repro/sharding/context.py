"""Logical-axis sharding context.

Models annotate activations with *logical* axis names via :func:`constrain`
and parameters with logical-spec tuples; the launcher installs a
:class:`Rules` object mapping logical names to mesh axes for the current
(mesh, input-shape) combination.  Outside any rules context every helper is
a no-op, so the same model code runs on a laptop CPU and on a 512-chip mesh.

Divisibility guard: a logical axis only shards a dimension if the dimension
is divisible by the product of mesh-axis sizes; otherwise it silently falls
back to replication (e.g. 4 kv heads cannot shard over model=16; batch=1 in
``long_500k`` cannot shard over data).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-tolerant ``shard_map``: top-level ``jax.shard_map`` on new
    JAX, ``jax.experimental.shard_map.shard_map`` (with its ``check_rep``
    spelling of the kwarg) on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

_STATE = threading.local()


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    table: Dict[str, MeshAxes]

    def axis_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def resolve(self, logical: Optional[str], dim: Optional[int]) -> MeshAxes:
        if logical is None:
            return None
        axes = self.table.get(logical)
        if axes is None:
            return None
        if dim is not None and dim % self.axis_size(axes):
            return None  # divisibility fallback -> replicate
        return axes

    def spec_for(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
        dims = list(shape) if shape is not None else [None] * len(logical_axes)
        used: set = set()
        parts = []
        for logical, dim in zip(logical_axes, dims):
            axes = self.resolve(logical, dim)
            # a mesh axis may appear at most once in a PartitionSpec
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else tuple(axes)
                if any(a in used for a in flat):
                    axes = None
                else:
                    used.update(flat)
            parts.append(axes)
        return P(*parts)

    def sharding_for(self, logical_axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


def set_rules(rules: Optional[Rules]) -> None:
    _STATE.rules = rules


def get_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield rules
    finally:
        set_rules(prev)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axis shardings (no-op without
    rules)."""
    rules = get_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    sh = rules.sharding_for(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, sh)


def is_spec(s) -> bool:
    """True for a logical-spec tuple: elements are None, axis names, or
    tuples of axis names (a logical axis may resolve to multiple mesh
    axes, e.g. batch -> ('pod', 'data'))."""
    def ok(a):
        return (a is None or isinstance(a, str)
                or (isinstance(a, tuple) and all(isinstance(x, str)
                                                 for x in a)))
    return isinstance(s, tuple) and all(ok(a) for a in s)


def param_sharding(specs_tree, params_tree, rules: Rules):
    """Resolve a logical-spec pytree against actual param shapes.

    ``params_tree`` may hold arrays or ShapeDtypeStructs.  A spec longer
    than the array rank (e.g. scalar placeholders for int leaves in
    optimizer state) resolves to full replication.
    """
    def resolve(spec, p):
        if len(spec) != len(p.shape):
            return NamedSharding(rules.mesh, P())
        return rules.sharding_for(spec, p.shape)

    return jax.tree.map(resolve, specs_tree, params_tree, is_leaf=is_spec)
