"""Sharding: logical-axis rules resolved against production meshes."""

from .axes import dp_axes, make_rules, tp_axis
from .context import (Rules, constrain, get_rules, param_sharding, set_rules,
                      use_rules)

__all__ = ["dp_axes", "make_rules", "tp_axis", "Rules", "constrain",
           "get_rules", "param_sharding", "set_rules", "use_rules"]
