"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2. [arXiv:2404.16821; hf]

The InternViT frontend is a STUB per the assignment: input_specs()
provides 256 precomputed patch embeddings prefixed to the text tokens;
the LM backbone (InternLM2-2B shape) is implemented fully.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    act="silu",
    frontend="vision_prefix",
    n_prefix=256,
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,
)
