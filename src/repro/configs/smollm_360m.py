"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
— llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

The faithful-CS showcase arch. route_share=0: the fully-unshared (R=1)
paper layout makes XLA materialize the per-group routed activations
(B*d_ff*G bytes — measured 610 GB/device at train_4k; see EXPERIMENTS.md
§Perf), so the production baseline uses modest route sharing; R=1 is
exercised at GSC scale and inside the Pallas kernels.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    d_head=64,
    act="silu",
    head_pad=16,   # 15 heads -> 16 computed (zero-masked) for TP divisibility
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,
)
