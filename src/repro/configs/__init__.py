"""Architecture config registry: ``get_config(arch_id)`` /
``list_archs()``.

One module per assigned architecture (exact public-literature configs; see
each file's source annotation) plus the paper's own ``gsc_cnn``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

ARCH_IDS = [
    "qwen3_moe_235b_a22b",
    "deepseek_v2_lite_16b",
    "starcoder2_15b",
    "yi_6b",
    "minitron_8b",
    "smollm_360m",
    "xlstm_350m",
    "zamba2_1p2b",
    "musicgen_large",
    "internvl2_2b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIAS.update({
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "starcoder2-15b": "starcoder2_15b",
    "yi-6b": "yi_6b",
    "minitron-8b": "minitron_8b",
    "smollm-360m": "smollm_360m",
    "xlstm-350m": "xlstm_350m",
    "zamba2-1.2b": "zamba2_1p2b",
    "musicgen-large": "musicgen_large",
    "internvl2-2b": "internvl2_2b",
})


def get_config(arch: str) -> ModelConfig:
    arch_id = _ALIAS.get(arch, arch)
    if arch_id not in ARCH_IDS and arch_id != "gsc_cnn":
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIAS)}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)


__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "TrainConfig",
           "get_config", "list_archs"]
