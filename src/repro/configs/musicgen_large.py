"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, D); the backbone is the transformer.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    frontend="embed",
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,
)
