"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]

CS packs the (huge) dense FFN: n=8 (87.5% weight sparsity) + 10% k-WTA
winners — the paper's §6.4 Transformer direction on the most FFN-heavy
assigned arch.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    ffn_sparsity=SparsityConfig(n=8, k_frac=0.10, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,
)
