"""Config dataclasses: model architecture, input shapes, mesh, training.

One ``ModelConfig`` per assigned architecture lives in repro/configs/<id>.py;
the same dataclass drives full-scale dry-runs and reduced smoke tests
(``reduced()``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.api import DENSE, SparsityConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm|cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    act: str = "silu"                # silu (SwiGLU) | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    vocab_pad: int = 128             # pad vocab to a multiple (TPU lanes)

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64

    # --- SSM / hybrid ---
    # The repeating unit of block kinds; n_layers must be a multiple of its
    # length. Entries: attn | mamba2 | mlstm | slstm | shared_attn.
    block_pattern: Tuple[str, ...] = ("attn",)
    ssm_state: int = 64
    ssm_chunk: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4

    # --- modality frontend stubs (audio/vlm) ---
    frontend: str = "none"           # none | embed (precomputed embeddings)
    n_prefix: int = 0                # prefix embeddings (vision patches)

    # --- the paper's technique ---
    ffn_sparsity: SparsityConfig = DENSE
    proj_sparsity: SparsityConfig = DENSE

    # --- numerics / memory ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    kv_cache_dtype: str = "bfloat16"   # "int8" halves decode cache bytes
    cache_write: str = "masked"        # "owner": shard_map row-owner write

    # --- attention scaling for long context ---
    flash_block: int = 512           # kv-chunk size for blockwise attention
    supports_long_context: bool = False  # sub-quadratic (SSM/hybrid) only

    # --- accounting: unroll inner (flash/SSD) scans so XLA cost analysis
    # counts every trip (used by the dry-run's per-unit compiles only) ---
    unroll_inner: bool = False

    # --- TP head padding (sharding-motivated, function-preserving) ---
    # When n_heads doesn't divide the model axis (smollm: 15 heads vs TP=16)
    # attention would replicate across TP. head_pad rounds the *computed*
    # head count up with dummy zero-masked heads: exact same function, but
    # the head axis shards. 0 = off.
    head_pad: int = 0

    def __post_init__(self):
        if self.n_layers % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"block_pattern length {len(self.block_pattern)}")

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        if not self.head_pad:
            return self.n_heads
        m = self.head_pad
        return ((self.n_heads + m - 1) // m) * m

    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab_size, self.vocab_pad
        return ((v + m - 1) // m) * m

    @property
    def n_units(self) -> int:
        """Number of scan steps (superblocks)."""
        return self.n_layers // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=2 * len(self.block_pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            d_head=16,
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            experts_per_token=min(self.experts_per_token, 2),
            kv_lora_rank=32 if self.use_mla else 0,
            rope_head_dim=8 if self.use_mla else self.rope_head_dim,
            ssm_state=16,
            ssm_chunk=16,
            ssm_head_dim=16,
            n_prefix=min(self.n_prefix, 4),
            flash_block=32,
        )
        base.update(overrides)
        # shrink sparsity configs to fit tiny dims
        if self.ffn_sparsity.weight_sparse:
            base.setdefault("ffn_sparsity",
                            dataclasses.replace(self.ffn_sparsity, n=4,
                                                route_share=0))
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The four assigned LM shapes (identical for all 10 archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    moment_dtype: str = "float32"     # bfloat16 = compressed optimizer state
    zero1: bool = True                # shard optimizer state over dp axes
    seed: int = 0
    microbatch: int = 0               # 0 = no gradient accumulation
    grad_compression: bool = False    # int8 error-feedback cross-pod sync
    checkpoint_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
