"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Head-dim note (DESIGN.md §7): the assignment sheet's d_model/heads gives
head_dim=64; we follow the sheet exactly.
CS (the paper's technique) packs the expert FFNs (n=4 -> 75% weight
sparsity) with k-WTA on the expert hidden (12.5% winners): MoE routing is
the coarse activation sparsity, CS+k-WTA the fine one.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    act="silu",
    n_experts=128,
    experts_per_token=8,
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,   # scan unit of 2 layers (47 units)
)
