"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA. [arXiv:2403.04652; hf]

d_ff=11008 = 4*2752: CS pack n=4 divides it exactly.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    act="silu",
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",) * 2,
)
