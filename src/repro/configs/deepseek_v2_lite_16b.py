"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE top-6, MLA kv_lora=512. [arXiv:2405.04434; hf]

Sheet discrepancy (DESIGN.md §7): "64e top-6" vs "2 shared + 160 routed";
160 routed is DeepSeek-V2 (236B). We implement the Lite spec: 64 routed +
2 shared, top-6.
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    act="silu",
    n_experts=64,
    n_shared_experts=2,
    experts_per_token=6,
    use_mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    block_pattern=("attn",),       # 27 units of 1 layer
)
