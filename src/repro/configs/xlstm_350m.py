"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (7:1 ratio as in the xLSTM paper's [7:1] notation).
[arXiv:2405.04517; unverified]

Sub-quadratic: runs the long_500k cell (O(1) recurrent state).
k-WTA is applied to block in/out projections only — never to the carried
recurrent state (DESIGN.md §7).
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),   # 3 units of 8
    ssm_chunk=128,
    supports_long_context=True,
)
