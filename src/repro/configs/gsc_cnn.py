"""The paper's own architecture (Table 1): GSC keyword-spotting CNN."""

from repro.models.gsc_cnn import GSCConfig

CONFIG = GSCConfig()
