"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE weight-shared attention
block invoked periodically (the Zamba trick). [arXiv:2411.15242; hf]

Pattern: 2 units x (18 mamba2 + 1 shared_attn) = 38 blocks; the shared_attn
params live outside the scan and are reused at every invocation.
Sub-quadratic: runs long_500k (Mamba2 state is O(1); the shared attention
KV cache seq-shards over the mesh).
"""

from repro.core.api import SparsityConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    act="gelu",
    block_pattern=("mamba2",) * 18 + ("shared_attn",),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=128,
    ffn_sparsity=SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="bisect"),
    supports_long_context=True,
)
