"""Recursive jaxpr traversal with name-stack paths and taint propagation.

JAX stages nested computations (``pjit``, ``scan``, ``while``, ``cond``,
``custom_vjp``/``custom_jvp``, ``remat``, ``pallas_call``) as jaxpr-valued
equation params.  The walker here flattens that hierarchy:

* :func:`iter_eqns` yields every equation with its accumulated name-stack
  path (``b0_attn/ffn_down/cs_topk/select``), so rules can attribute a
  primitive to the layer that staged it.  Scan/while bodies are visited
  once — matching the "per traced superblock" accounting of the model's
  ``lax.scan`` layer stack.
* :func:`propagate_taint` runs a forward may-analysis over the same
  hierarchy: variables produced by *source* primitives are tainted, taint
  flows through every equation except designated *sinks*, and each
  (tainted-input, flagged-primitive) hit is reported.  Used by the
  dense-fallback rule: sources = ``top_k`` (the Select), sink =
  ``pallas_call`` (the sanctioned sparse consumer), flagged =
  ``dot_general``.

Sub-jaxpr inputs/outputs are aligned to the outer equation's operands by
suffix: every jaxpr-carrying primitive in JAX (pjit, scan, while, cond,
custom_* calls, remat) passes its operands as the *trailing* invars of the
inner jaxpr (leading positions are consts / carry prefixes that are also
operands), so suffix alignment is exact for pjit/scan/remat/custom and a
safe over-approximation for while/cond.
"""

from __future__ import annotations

from typing import Callable, Iterator, List, NamedTuple, Sequence, Tuple

from jax._src import core as jax_core

Jaxpr = jax_core.Jaxpr
ClosedJaxpr = jax_core.ClosedJaxpr
Var = jax_core.Var


def _as_jaxpr(obj) -> Jaxpr:
    return obj.jaxpr if isinstance(obj, ClosedJaxpr) else obj


def sub_jaxprs(eqn) -> List[Jaxpr]:
    """All jaxpr-valued params of an equation (flattening tuples/lists)."""
    out: List[Jaxpr] = []
    for val in eqn.params.values():
        items = val if isinstance(val, (tuple, list)) else (val,)
        for it in items:
            if isinstance(it, (Jaxpr, ClosedJaxpr)):
                out.append(_as_jaxpr(it))
    return out


def _join(prefix: str, name_stack: str) -> str:
    if prefix and name_stack:
        return f"{prefix}/{name_stack}"
    return prefix or name_stack


def eqn_path(eqn, prefix: str = "") -> str:
    """Accumulated name-stack path of one equation."""
    try:
        ns = str(eqn.source_info.name_stack)
    except AttributeError:           # pragma: no cover - very old jax
        ns = ""
    return _join(prefix, ns)


class EqnAt(NamedTuple):
    eqn: jax_core.JaxprEqn
    path: str
    depth: int


def iter_eqns(jaxpr, prefix: str = "", depth: int = 0,
              into_pallas: bool = True) -> Iterator[EqnAt]:
    """Yield every equation (recursively) with its name-stack path.

    ``into_pallas=False`` stops at ``pallas_call`` boundaries (the kernel
    body is a different machine model; rules that only make sense at the
    XLA level skip it)."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        path = eqn_path(eqn, prefix)
        yield EqnAt(eqn, path, depth)
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, path, depth + 1, into_pallas)


class TaintHit(NamedTuple):
    eqn: jax_core.JaxprEqn
    path: str


def propagate_taint(jaxpr,
                    source_prims: Sequence[str],
                    sink_prims: Sequence[str],
                    flag_prims: Sequence[str],
                    prefix: str = "",
                    in_taint: Sequence[bool] = ()) -> Tuple[List[bool],
                                                            List[TaintHit]]:
    """Forward taint propagation; returns (outvar taint, flagged hits).

    * outputs of any ``source_prims`` equation are tainted;
    * ``sink_prims`` consume taint (their outputs are clean, and their
      sub-jaxprs are not entered);
    * a ``flag_prims`` equation with any tainted input is reported;
    * every other equation propagates any-input-tainted -> all outputs.
    """
    jaxpr = _as_jaxpr(jaxpr)
    taint = {}
    invals = list(in_taint) + [False] * (len(jaxpr.invars) - len(in_taint))
    for v, t in zip(jaxpr.invars, invals):
        taint[v] = t
    for v in jaxpr.constvars:
        taint[v] = False
    hits: List[TaintHit] = []

    def var_taint(v) -> bool:
        return isinstance(v, Var) and taint.get(v, False)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        path = eqn_path(eqn, prefix)
        in_ts = [var_taint(v) for v in eqn.invars]
        any_in = any(in_ts)
        if name in flag_prims and any_in:
            hits.append(TaintHit(eqn, path))
        if name in source_prims:
            out_ts = [True] * len(eqn.outvars)
        elif name in sink_prims:
            out_ts = [False] * len(eqn.outvars)
        else:
            subs = sub_jaxprs(eqn)
            if subs:
                out_ts = [False] * len(eqn.outvars)
                for sub in subs:
                    # suffix-align outer operands to inner invars
                    n_in = len(_as_jaxpr(sub).invars)
                    inner_in = in_ts[len(in_ts) - n_in:] if n_in else []
                    if n_in > len(in_ts):
                        inner_in = [False] * (n_in - len(in_ts)) + in_ts
                    sub_out, sub_hits = propagate_taint(
                        sub, source_prims, sink_prims, flag_prims,
                        prefix=path, in_taint=inner_in)
                    hits.extend(sub_hits)
                    # suffix-align inner outvars to outer outvars
                    n_out = min(len(sub_out), len(eqn.outvars))
                    for i in range(n_out):
                        if sub_out[len(sub_out) - n_out + i]:
                            out_ts[len(eqn.outvars) - n_out + i] = True
            else:
                out_ts = [any_in] * len(eqn.outvars)
        for v, t in zip(eqn.outvars, out_ts):
            if isinstance(v, Var):
                taint[v] = taint.get(v, False) or t
    return [var_taint(v) for v in jaxpr.outvars], hits
