"""Structured findings emitted by the sparsity-invariant linter.

A :class:`Finding` is one violated invariant, pinned to a rule id, an
entrypoint, a layer scope (the jaxpr ``name_stack`` path, e.g.
``b0_attn/ffn_down/cs_topk``) and the offending primitive.  A
:class:`Report` aggregates findings across rules/entrypoints and supports
waivers (exact rule ids or ``rule:scope-prefix`` pairs) so a known,
deliberate exception can be recorded without disabling the rule globally.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Optional, Sequence, Tuple

#: Severity levels, in increasing order of badness.
SEVERITIES = ("info", "warning", "error")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated sparsity invariant.

    Attributes:
      rule: stable rule id (``select-count``, ``dense-fallback``,
        ``dtype-promotion``, ``pallas-resource``, ``hlo-collective``,
        ``hlo-host-transfer``).
      message: human-readable description of the violation.
      entry: the linted entrypoint (``decode``, ``prefill``, ...).
      scope: jaxpr name-stack path of the offending equation ("" when the
        finding is not attributable to a scope, e.g. HLO-level findings).
      primitive: offending primitive / HLO op name ("" when n/a).
      severity: ``info`` | ``warning`` | ``error``.
    """

    rule: str
    message: str
    entry: str = ""
    scope: str = ""
    primitive: str = ""
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def render(self) -> str:
        where = "/".join(p for p in (self.entry, self.scope) if p)
        prim = f" [{self.primitive}]" if self.primitive else ""
        return f"{self.severity}: {self.rule} @ {where or '<module>'}" \
               f"{prim}: {self.message}"

    def matches_waiver(self, waiver: str) -> bool:
        """A waiver is ``rule`` or ``rule:scope-prefix``."""
        if ":" not in waiver:
            return self.rule == waiver
        rule, prefix = waiver.split(":", 1)
        return self.rule == rule and self.scope.startswith(prefix)


@dataclasses.dataclass
class Report:
    """Lint results: surviving findings plus the waived ones."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    waived: List[Finding] = dataclasses.field(default_factory=list)
    #: entrypoints that were actually linted (for "did it even run" checks)
    entries: List[str] = dataclasses.field(default_factory=list)

    def add(self, findings: Iterable[Finding],
            waivers: Sequence[str] = ()) -> None:
        for f in findings:
            if any(f.matches_waiver(w) for w in waivers):
                self.waived.append(f)
            else:
                self.findings.append(f)

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.waived.extend(other.waived)
        self.entries.extend(other.entries)

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.findings

    def render(self) -> str:
        lines = []
        if not self.findings:
            lines.append(f"clean: 0 findings over "
                         f"{', '.join(self.entries) or 'no entrypoints'}")
        else:
            lines.append(f"{len(self.findings)} finding(s):")
            lines.extend("  " + f.render() for f in self.findings)
        if self.waived:
            lines.append(f"{len(self.waived)} waived:")
            lines.extend("  " + f.render() for f in self.waived)
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "entries": self.entries,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "waived": [dataclasses.asdict(f) for f in self.waived],
        }, indent=2)
