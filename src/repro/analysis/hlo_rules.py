"""HLO-level lint rules: host transfers and unexpected collectives.

The jaxpr rules prove properties of the *staged* program; these rules
check what the compiler actually emitted.  They parse compiled HLO text
via :mod:`repro.launch.hlo` — the decode step must stay on-device
(``hlo-host-transfer``) and must not sprout collectives the sharding
plan didn't ask for (``hlo-collective``).
"""

from __future__ import annotations

from typing import List, Sequence

from repro.launch.hlo import collective_stats, host_transfer_ops

from .findings import Finding

#: Collective kinds tracked by launch/hlo.py.
KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def rule_hlo_host_transfer(hlo_text: str, entry: str = "") -> List[Finding]:
    """Any host/device boundary crossing on the linted path is an error.

    A single host round-trip costs more than an entire decode step; the
    sparse-sparse path must be resident."""
    out: List[Finding] = []
    for kind, line in host_transfer_ops(hlo_text):
        out.append(Finding(
            rule="hlo-host-transfer", entry=entry, primitive=kind,
            message=f"host transfer in compiled HLO: {line[:160]}"))
    return out


def rule_hlo_collectives(hlo_text: str, entry: str = "",
                         allowed: Sequence[str] = ()) -> List[Finding]:
    """Collectives outside the ``allowed`` kinds are errors.

    The message carries byte totals and how many instances sit inside
    while-loop bodies (those run once per scan trip — n_units times for
    the layer stack — so they dominate even when the flat count looks
    small)."""
    stats = collective_stats(hlo_text)
    out: List[Finding] = []
    for kind in KINDS:
        count = int(stats.get(f"{kind}_count", 0))
        if not count or kind in allowed:
            continue
        nbytes = int(stats.get(f"{kind}_bytes", 0))
        in_while = int(stats.get(f"{kind}_in_while_count", 0))
        out.append(Finding(
            rule="hlo-collective", entry=entry, primitive=kind,
            message=f"unexpected {kind} x{count} ({nbytes} bytes per "
                    f"execution, {in_while} inside while bodies) in the "
                    f"compiled {entry or 'entry'} module"))
    return out
