"""Static analysis of the sparse-sparse execution paths.

A linter that *proves* — from staged jaxprs and compiled HLO, without
running the model — that the complementary-sparsity invariants hold:
one Select per sparse layer (paper Fig. 8a), the k-sparse support is
consumed by the Pallas kernel (never a dense ``dot_general``), no
float64 leaks into kernels, every ``pallas_call`` BlockSpec fits its
array and VMEM, and the compiled decode step stays on-device.

Entry points:

* ``analysis.lint_fn(fn, *args)`` — lint any traceable callable.
* ``analysis.lint_config("smollm_360m")`` — lint a named config's
  decode/prefill/kernel/train entrypoints abstractly.
* ``python -m repro.analysis --config smollm_360m --fail-on-findings``
  — the CI job.

See README.md in this directory for the rule catalogue and how to
waive a finding.
"""

from .findings import SEVERITIES, Finding, Report
from .hlo_rules import rule_hlo_collectives, rule_hlo_host_transfer
from .intervals import AbsVal, Interval, Sym
from .jaxpr_walk import iter_eqns, propagate_taint, sub_jaxprs
from .kernel_rules import (register_value_ranges, rule_kernel_body,
                           verify_pallas_eqn)
from .lint import (ENTRIES, expected_selects, family_path, family_selects,
                   lint_config, lint_fn, lint_hlo, lint_kernel_pipeline,
                   lint_kernels, seeded_regressions, self_test)
from .rules import (SELECT_PRIMS, layer_key, rule_dense_fallback,
                    rule_dtype_promotion, rule_pallas_resource,
                    rule_select_count)

__all__ = [
    "AbsVal", "ENTRIES", "Finding", "Interval", "Report", "SELECT_PRIMS",
    "SEVERITIES", "Sym", "expected_selects", "family_path",
    "family_selects", "iter_eqns", "layer_key", "lint_config", "lint_fn",
    "lint_hlo", "lint_kernel_pipeline", "lint_kernels", "propagate_taint",
    "register_value_ranges", "rule_dense_fallback", "rule_dtype_promotion",
    "rule_hlo_collectives", "rule_hlo_host_transfer", "rule_kernel_body",
    "rule_pallas_resource", "rule_select_count", "seeded_regressions",
    "self_test", "sub_jaxprs", "verify_pallas_eqn",
]
