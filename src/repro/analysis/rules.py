"""Jaxpr-level lint rules for the sparsity invariants.

Each rule is a pure function ``(closed_jaxpr, ctx...) -> List[Finding]``
over a traced entrypoint.  Layer attribution relies on the
``jax.named_scope`` annotations the model code stages (``b{i}_{kind}``
block scopes, ``ffn_up``/``ffn_gate``/``ffn_kwta``/``ffn_down`` and
``o_proj`` family scopes, ``cs_{path}`` execution-path scopes,
``select`` around every counted ``lax.top_k``).

Rules
-----
``select-count``     one Select (top_k) per sparse layer (paper Fig. 8a)
``dense-fallback``   the k-sparse support must reach the Pallas kernel,
                     never a ``dot_general`` (sparse-sparse stays sparse)
``dtype-promotion``  no float64 staging; no implicit widening inside
                     Pallas kernel bodies
``pallas-resource``  every ``pallas_call`` BlockSpec divides its array,
                     fits the grid, and the per-step blocks fit VMEM
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.kernels.block_validation import (check_block_shape,
                                            estimate_vmem_bytes, vmem_budget)

from .findings import Finding
from .jaxpr_walk import iter_eqns, propagate_taint, sub_jaxprs

#: Primitives that implement a Select (top-k winner choice).  ``sort`` is
#: counted too: a sort-based k-WTA is a Select with a worse lowering.
SELECT_PRIMS = ("top_k", "approx_top_k", "sort")

#: Family markers staged by models/ffn.py and models/attention.py.
_FAMILY_OF_SEG = {"o_proj": "o_proj"}
_BLOCK_SEG = re.compile(r"^b\d+_")


def layer_key(path: str) -> str:
    """Collapse a name-stack path to its sparse-layer key.

    ``b0_attn/ffn_down/cs_topk/select`` -> ``b0_attn/ffn``;
    ``b1_attn/o_proj/...`` -> ``b1_attn/o_proj``; paths outside any
    family scope collapse to their block prefix (or "")."""
    blocks: List[str] = []
    for seg in path.split("/"):
        if _BLOCK_SEG.match(seg):
            blocks.append(seg)
            continue
        fam = _FAMILY_OF_SEG.get(seg)
        if fam is None and seg.startswith("ffn_"):
            fam = "ffn"
        if fam is not None:
            return "/".join(blocks + [fam])
    return "/".join(blocks)


# ---------------------------------------------------------------------------
# Rule: select-count
# ---------------------------------------------------------------------------

def rule_select_count(closed_jaxpr, expected: Optional[Dict[str, int]],
                      entry: str = "") -> List[Finding]:
    """One Select per sparse layer (paper Fig. 8a).

    ``expected`` maps layer keys (see :func:`layer_key`) to the number of
    Select primitives the configuration should stage — computed by
    ``repro.analysis.lint.expected_selects`` from the same dispatch rules
    the layers use.  ``None`` skips the rule (un-modeled config, e.g. MoE
    routers)."""
    if expected is None:
        return []
    actual: Dict[str, int] = {}
    where: Dict[str, str] = {}
    for eqn, path, _ in iter_eqns(closed_jaxpr, into_pallas=False):
        if eqn.primitive.name not in SELECT_PRIMS:
            continue
        key = layer_key(path)
        actual[key] = actual.get(key, 0) + 1
        where.setdefault(key, path)
    out: List[Finding] = []
    for key, exp in sorted(expected.items()):
        got = actual.get(key, 0)
        if got > exp:
            out.append(Finding(
                rule="select-count", entry=entry, scope=key,
                primitive="top_k",
                message=f"layer {key or '<entry>'} stages {got} Select "
                        f"primitives, expected {exp} (one Select per sparse "
                        f"layer; first at {where.get(key, key)!r})"))
        elif got < exp:
            out.append(Finding(
                rule="select-count", entry=entry, scope=key,
                primitive="top_k", severity="warning",
                message=f"layer {key or '<entry>'} stages {got} Select "
                        f"primitives, model expected {exp} — the Select "
                        f"model in analysis/lint.py is out of date"))
    for key, got in sorted(actual.items()):
        if key in expected or not key:
            continue
        fam = key.rsplit("/", 1)[-1]
        if fam in ("ffn", "o_proj"):
            out.append(Finding(
                rule="select-count", entry=entry, scope=key,
                primitive="top_k",
                message=f"unmodeled sparse layer {key} stages {got} Select "
                        f"primitives (first at {where[key]!r})"))
    return out


# ---------------------------------------------------------------------------
# Rule: dense-fallback
# ---------------------------------------------------------------------------

def rule_dense_fallback(closed_jaxpr, entry: str = "") -> List[Finding]:
    """The k-sparse support must be consumed by a Pallas kernel.

    Taint flows from every ``top_k`` output (the Select's ``(vals, idx)``
    support); ``pallas_call`` is the sanctioned sink.  A ``dot_general``
    (or conv) touching tainted data means the sparse-sparse contraction
    fell back to dense math — the paper's FLOP savings silently vanish.

    Only meaningful when the entrypoint is configured for the Pallas
    topk path (``use_pallas`` on and the regime dispatch picks ``topk``);
    the caller gates on that."""
    _, hits = propagate_taint(
        closed_jaxpr,
        source_prims=("top_k", "approx_top_k"),
        sink_prims=("pallas_call",),
        flag_prims=("dot_general", "conv_general_dilated"))
    out = []
    for eqn, path in hits:
        key = layer_key(path)
        out.append(Finding(
            rule="dense-fallback", entry=entry, scope=path,
            primitive=eqn.primitive.name,
            message=f"{eqn.primitive.name} consumes the k-sparse Select "
                    f"support in layer {key or '<entry>'} — expected the "
                    f"Pallas sparse-sparse kernel (use_pallas is on); the "
                    f"contraction fell back to dense math"))
    return out


# ---------------------------------------------------------------------------
# Rule: dtype-promotion
# ---------------------------------------------------------------------------

#: Widening through these is sanctioned (explicit casts; f32 accumulation).
_PROMOTION_EXEMPT = frozenset({
    "convert_element_type", "dot_general", "conv_general_dilated",
    "pallas_call", "iota", "reduce_sum", "reduce_max", "reduce_min",
    "cumsum", "integer_pow",
})

_WIDE_DTYPES = ("float64", "complex128")


def _float_width(dtype) -> Optional[int]:
    dt = np.dtype(dtype)
    return dt.itemsize if dt.kind == "f" else None


def _iter_kernel_jaxprs(closed_jaxpr):
    for eqn, path, _ in iter_eqns(closed_jaxpr, into_pallas=False):
        if eqn.primitive.name == "pallas_call":
            for sub in sub_jaxprs(eqn):
                yield sub, path


def rule_dtype_promotion(closed_jaxpr, entry: str = "") -> List[Finding]:
    """No float64 staging anywhere; no implicit widening in kernel bodies.

    f64 (usually a weak-typed Python scalar under ``enable_x64``) doubles
    kernel VMEM traffic and falls off the TPU fast path entirely.  Inside
    Pallas kernel bodies we additionally flag *implicit* float widening by
    elementwise ops — accumulating in f32 is fine when explicit
    (``convert_element_type`` / ``preferred_element_type``), invisible
    promotion is not."""
    out: List[Finding] = []
    for eqn, path, _ in iter_eqns(closed_jaxpr, into_pallas=True):
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and str(dt) in _WIDE_DTYPES:
                out.append(Finding(
                    rule="dtype-promotion", entry=entry, scope=path,
                    primitive=eqn.primitive.name,
                    message=f"{eqn.primitive.name} stages a {dt} value in "
                            f"{layer_key(path) or '<entry>'} — 64-bit types "
                            f"must never reach the sparse kernels"))
                break
    for kernel, kpath in _iter_kernel_jaxprs(closed_jaxpr):
        for eqn, path, _ in iter_eqns(kernel, prefix=kpath):
            if eqn.primitive.name in _PROMOTION_EXEMPT:
                continue
            in_w = [_float_width(v.aval.dtype) for v in eqn.invars
                    if getattr(v, "aval", None) is not None
                    and hasattr(v.aval, "dtype")]
            in_w = [w for w in in_w if w]
            out_w = [_float_width(v.aval.dtype) for v in eqn.outvars
                     if hasattr(getattr(v, "aval", None), "dtype")]
            out_w = [w for w in out_w if w]
            if in_w and out_w and max(out_w) > max(in_w):
                out.append(Finding(
                    rule="dtype-promotion", entry=entry, scope=path,
                    primitive=eqn.primitive.name, severity="warning",
                    message=f"implicit float widening ({8 * max(in_w)}->"
                            f"{8 * max(out_w)} bit) by {eqn.primitive.name} "
                            f"inside a Pallas kernel body"))
    return out


# ---------------------------------------------------------------------------
# Rule: pallas-resource
# ---------------------------------------------------------------------------

def _block_shape_ints(block_shape) -> tuple:
    return tuple(int(b) if isinstance(b, (int, np.integer)) else 1
                 for b in block_shape)


def rule_pallas_resource(closed_jaxpr, entry: str = "",
                         backend: str = "tpu") -> List[Finding]:
    """Static resource check of every staged ``pallas_call``.

    Re-validates what :mod:`repro.kernels.block_validation` enforced at
    call time — but on the *staged* program, so a kernel wrapper that
    skipped validation (or a grid computed from bad shapes) is still
    caught: every BlockSpec must divide its array shape, and the sum of
    per-grid-step blocks must fit the VMEM lint budget."""
    out: List[Finding] = []
    budget = vmem_budget(backend)
    for eqn, path, _ in iter_eqns(closed_jaxpr, into_pallas=False):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params.get("grid_mapping")
        if gm is None:                      # pragma: no cover - API drift
            out.append(Finding(
                rule="pallas-resource", entry=entry, scope=path,
                primitive="pallas_call", severity="warning",
                message="pallas_call without grid_mapping param; cannot "
                        "check BlockSpecs (jax API drift?)"))
            continue
        name = str(eqn.params.get("name_and_src_info", "pallas_call"))
        name = name.split(" ")[0]
        blocks = []
        for bm in gm.block_mappings:
            arr = bm.array_shape_dtype
            for problem in check_block_shape(bm.block_shape, arr.shape):
                out.append(Finding(
                    rule="pallas-resource", entry=entry, scope=path,
                    primitive=name,
                    message=f"kernel {name}: BlockSpec "
                            f"{_block_shape_ints(bm.block_shape)} vs array "
                            f"{tuple(arr.shape)}: {problem}"))
            blocks.append((bm.block_shape, arr.dtype))
        vmem = estimate_vmem_bytes(blocks)
        if vmem > budget:
            out.append(Finding(
                rule="pallas-resource", entry=entry, scope=path,
                primitive=name,
                message=f"kernel {name}: per-grid-step blocks need "
                        f"{vmem} bytes of VMEM, over the {backend} lint "
                        f"budget of {budget} bytes"))
        grid = tuple(getattr(gm, "grid", ()) or ())
        for axis, extent in enumerate(grid):
            if isinstance(extent, (int, np.integer)) and extent < 1:
                out.append(Finding(
                    rule="pallas-resource", entry=entry, scope=path,
                    primitive=name,
                    message=f"kernel {name}: grid axis {axis} has extent "
                            f"{int(extent)}"))
    return out
