"""Interval / affine abstract domain for the kernel-body verifier.

The verifier (:mod:`repro.analysis.kernel_rules`) runs an abstract
interpreter over staged Pallas kernel jaxprs.  Its values live in the
domain implemented here:

* :class:`Interval` — a closed integer/float interval ``[lo, hi]``
  (``±inf`` allowed), with sound arithmetic.
* :class:`Sym` — an opaque symbol with a known range: one per
  ``pl.program_id`` axis (range ``[0, grid[axis])``), one per scan
  iteration counter (range ``[0, length)``), one per widened loop carry.
* :class:`AbsVal` — an affine combination ``Σ coeff·sym + base`` where
  ``base`` is an :class:`Interval`.  The affine part is what lets the
  analysis prove ``fori_loop`` induction bounds exactly (``q = iter``
  with ``iter ∈ [0, k_nnz)``) instead of widening to ``±inf``; anything
  non-affine falls back to the pure interval.

Besides the numeric abstraction, an :class:`AbsVal` carries two taint
sets used by the rules:

* ``reads`` — which kernel Refs the value was (transitively) loaded
  from; a store whose value read the same Ref is a read-modify-write
  (the ``grid-race`` accumulation discipline).
* ``pad`` — which Refs with a *partial trailing block* the value was
  loaded from without passing through a mask; a ``select_n`` whose
  predicate is pad-clean launders it (the ``unmasked-pad`` rule).

and an optional ``pred`` annotation recognizing the ``program_id(axis)
== 0`` predicates that guard init stores.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Optional, Tuple

NEG_INF = float("-inf")
POS_INF = float("inf")

_sym_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class Interval:
    """A closed interval [lo, hi]; lo/hi may be ±inf."""

    lo: float
    hi: float

    @staticmethod
    def const(c) -> "Interval":
        c = float(c)
        return Interval(c, c)

    @staticmethod
    def top() -> "Interval":
        return Interval(NEG_INF, POS_INF)

    @property
    def is_top(self) -> bool:
        return self.lo == NEG_INF and self.hi == POS_INF

    @property
    def is_point(self) -> bool:
        return self.lo == self.hi and self.lo not in (NEG_INF, POS_INF)

    def __add__(self, o: "Interval") -> "Interval":
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def __sub__(self, o: "Interval") -> "Interval":
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def __mul__(self, o: "Interval") -> "Interval":
        cands = [_mul(a, b) for a in (self.lo, self.hi)
                 for b in (o.lo, o.hi)]
        return Interval(min(cands), max(cands))

    def join(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), max(self.hi, o.hi))

    def meet(self, o: "Interval") -> "Interval":
        return Interval(max(self.lo, o.lo), min(self.hi, o.hi))

    def scale(self, k: float) -> "Interval":
        a, b = _mul(self.lo, k), _mul(self.hi, k)
        return Interval(min(a, b), max(a, b))

    def floordiv(self, k: float) -> "Interval":
        if k <= 0:
            return Interval.top()
        lo = self.lo // k if self.lo not in (NEG_INF, POS_INF) else self.lo
        hi = self.hi // k if self.hi not in (NEG_INF, POS_INF) else self.hi
        return Interval(lo, hi)

    def render(self) -> str:
        def f(v):
            if v == NEG_INF:
                return "-inf"
            if v == POS_INF:
                return "inf"
            return str(int(v)) if float(v).is_integer() else f"{v:g}"
        return f"[{f(self.lo)}, {f(self.hi)}]"


def _mul(a: float, b: float) -> float:
    # inf * 0 -> 0 (sound for interval corners: the 0-extreme dominates)
    if a == 0 or b == 0:
        return 0.0
    return a * b


TOP = Interval.top()


@dataclasses.dataclass(frozen=True, eq=False)
class Sym:
    """An opaque symbolic quantity with a known range.

    ``kind`` is ``"pid"`` (a grid index; ``axis`` set), ``"iter"`` (a
    scan/loop iteration counter) or ``"carry"`` (a widened loop carry).
    Identity is object identity — two symbols never alias.
    """

    name: str
    range: Interval
    kind: str = "opaque"
    axis: Optional[int] = None

    @staticmethod
    def fresh(name: str, rng: Interval, kind: str = "opaque",
              axis: Optional[int] = None) -> "Sym":
        return Sym(f"{name}#{next(_sym_counter)}", rng, kind, axis)


#: Predicate annotation: ("pid_eq0", axis) — ``program_id(axis) == 0``.
Pred = Tuple[str, int]


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Abstract value: affine form + taint metadata.

    ``terms`` maps :class:`Sym` -> integer coefficient; the concrete
    value lies in ``base + Σ coeff · sym.range``.  An empty ``terms``
    is a plain interval.
    """

    base: Interval = TOP
    terms: Tuple[Tuple[Sym, float], ...] = ()
    reads: FrozenSet[int] = frozenset()
    pad: FrozenSet[int] = frozenset()
    pred: Optional[Pred] = None

    # -- constructors -------------------------------------------------------

    @staticmethod
    def const(c) -> "AbsVal":
        return AbsVal(base=Interval.const(c))

    @staticmethod
    def interval(lo, hi, **meta) -> "AbsVal":
        return AbsVal(base=Interval(float(lo), float(hi)), **meta)

    @staticmethod
    def top(**meta) -> "AbsVal":
        return AbsVal(base=TOP, **meta)

    @staticmethod
    def of_sym(sym: Sym) -> "AbsVal":
        return AbsVal(base=Interval.const(0), terms=((sym, 1.0),))

    # -- interrogation ------------------------------------------------------

    def iv(self) -> Interval:
        """Concretize to an interval."""
        out = self.base
        for sym, coeff in self.terms:
            out = out + sym.range.scale(coeff)
        return out

    @property
    def is_const(self) -> bool:
        return not self.terms and self.base.is_point

    def term_map(self) -> Dict[Sym, float]:
        return dict(self.terms)

    def meta(self, *others: "AbsVal") -> dict:
        reads = self.reads
        pad = self.pad
        for o in others:
            reads = reads | o.reads
            pad = pad | o.pad
        return {"reads": reads, "pad": pad}

    def with_meta(self, **meta) -> "AbsVal":
        return dataclasses.replace(self, pred=None, **meta)

    # -- arithmetic ---------------------------------------------------------

    def add(self, o: "AbsVal") -> "AbsVal":
        terms = self.term_map()
        for sym, coeff in o.terms:
            terms[sym] = terms.get(sym, 0.0) + coeff
        terms = tuple((s, c) for s, c in terms.items() if c != 0.0)
        return AbsVal(base=self.base + o.base, terms=terms, **self.meta(o))

    def neg(self) -> "AbsVal":
        return AbsVal(base=Interval(-self.base.hi, -self.base.lo),
                      terms=tuple((s, -c) for s, c in self.terms),
                      reads=self.reads, pad=self.pad)

    def sub(self, o: "AbsVal") -> "AbsVal":
        r = self.add(o.neg())
        return dataclasses.replace(r, **self.meta(o))

    def mul(self, o: "AbsVal") -> "AbsVal":
        if not o.terms and o.base.is_point:
            k = o.base.lo
            return AbsVal(base=self.base.scale(k),
                          terms=tuple((s, c * k) for s, c in self.terms
                                      if c * k != 0.0),
                          **self.meta(o))
        if not self.terms and self.base.is_point:
            return o.mul(self)
        return AbsVal(base=self.iv() * o.iv(), **self.meta(o))

    def join(self, o: "AbsVal") -> "AbsVal":
        if self.terms == o.terms:
            return AbsVal(base=self.base.join(o.base), terms=self.terms,
                          **self.meta(o))
        return AbsVal(base=self.iv().join(o.iv()), **self.meta(o))

    def render(self) -> str:
        parts = [f"{c:g}*{s.name}" for s, c in self.terms]
        parts.append(self.base.render())
        return " + ".join(parts)
