"""Kernel-body verifier: symbolic bounds, race and masking proofs for the
Pallas sparse-sparse kernels.

PR 6's jaxpr/HLO linter stops at the ``pallas_call`` boundary; the rules
here step *inside* it.  Each staged kernel body is re-interpreted over the
interval/affine domain of :mod:`repro.analysis.intervals`, with
``pl.program_id`` values bound to symbols ranging over the grid and loop
counters recovered by induction analysis (a ``fori_loop`` stages as a
static-length ``scan``; its counter carry is recognized as ``init +
iter·stride`` with ``iter ∈ [0, length)``).  Four rule families come out
of one abstract pass:

``oob-access``
    Every Ref load/store index interval must fit the Ref's block shape —
    including ``pl.ds`` slices whose start is a traced value.  Data-
    dependent gathers (the ``p_idx`` rows of ``topk_gather``) are bounded
    by *provenance*: the kernel registry declares the value range of each
    index-carrying operand (``p_idx`` from ``top_k`` over ``P``
    partitions ⇒ ``[0, P)``), and the verifier proves every derived
    access stays inside the block.  An index the analysis cannot bound is
    a finding, not a pass — these are proofs, not heuristics.

``grid-race``
    An output Ref whose BlockSpec index map ignores a grid axis is
    revisited across that axis's steps.  Writes to it must follow the
    init-then-accumulate discipline: one full-block store guarded by
    ``pl.when(program_id(axis) == 0)`` dominating every read-modify-write.
    A missing init (RMW of uninitialized VMEM on the first visit) or an
    unguarded plain overwrite (last-writer-wins across steps) is flagged.

``unmasked-pad``
    When an array dim is not divisible by its block, the trailing block
    is padded; loads from such a Ref carry a pad taint that only a
    ``select_n`` (``jnp.where``) with a pad-clean predicate launders.
    Pad-tainted data reaching an output Ref is flagged.

``scratch-overflow``
    ``scratch_shapes`` buffers are folded into the per-grid-step VMEM
    working set (on top of the BlockSpec buffers that ``pallas-resource``
    already accounts) and checked against the lint budget.

Soundness notes: value-range provenance is declared, not derived — the
registry entry documents *why* each range holds (see
``repro.kernels.registry``); Ref-mediated dataflow through scratch
buffers preserves read/pad taint via the Ref's accumulated store taint;
``while`` loops (traced-bound ``fori_loop``) widen carries to ``±inf``,
which can only add findings, never hide one.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax import tree_util

from repro.kernels.block_validation import (block_bytes, estimate_vmem_bytes,
                                            vmem_budget)

from .findings import Finding
from .intervals import TOP, AbsVal, Interval, Sym
from .jaxpr_walk import iter_eqns, sub_jaxprs

# ---------------------------------------------------------------------------
# Ref bookkeeping
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class RefInfo:
    """One kernel operand Ref: block geometry + declared value range."""

    idx: int                      # body invar position
    kind: str                     # "index" | "in" | "out" | "scratch"
    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]  # == block_shape for scratch
    dtype: object
    value_range: Optional[Interval] = None   # declared element range
    padded_axes: Tuple[int, ...] = ()        # axes with a partial block
    # taint accumulated by stores, returned by subsequent loads (sound
    # Ref-mediated dataflow through scratch/output buffers)
    stored_reads: frozenset = frozenset()
    stored_pad: frozenset = frozenset()

    @property
    def label(self) -> str:
        shape = "x".join(str(d) for d in self.block_shape)
        return f"{self.kind}[{self.idx}] {np.dtype(self.dtype).name}[{shape}]"


@dataclasses.dataclass(frozen=True)
class Access:
    ref: RefInfo
    kind: str          # "read" | "write" | "accum"
    order: int
    guards: Tuple[tuple, ...]
    full_block: bool


def _is_init_guard(guards: Tuple[tuple, ...], revisited: Sequence[int]) -> bool:
    return any(g and g[0] == "pid_eq0" and g[1] in revisited for g in guards)


# ---------------------------------------------------------------------------
# Value-range provenance registry
# ---------------------------------------------------------------------------

#: kernel body function name -> fn(refs: List[RefInfo]) -> {operand: Interval}
_VALUE_RANGES: Dict[str, Callable[[List[RefInfo]], Dict[int, Interval]]] = {}


def register_value_ranges(kernel_name: str,
                          fn: Callable[[List[RefInfo]],
                                       Dict[int, Interval]]) -> None:
    """Declare the element ranges of a kernel's index-carrying operands.

    ``kernel_name`` is the staged kernel body function name (the first
    token of the ``pallas_call`` eqn's ``name_and_src_info``).  ``fn``
    receives the operand :class:`RefInfo` list and returns a mapping
    from operand position to the :class:`Interval` its *values* are
    guaranteed to lie in.  The declaration is the verifier's trust root:
    register it next to the wrapper that constructs those operands, with
    a comment saying why the range holds.
    """
    _VALUE_RANGES[kernel_name] = fn


def _apply_provenance(kernel_name: str, refs: List[RefInfo]) -> None:
    # The shipped kernels' declarations live in repro.kernels.registry;
    # registration is lazy (first verification) to avoid the circular
    # import between the registry and this module.
    try:
        from repro.kernels import registry
        registry.ensure_provenance()
    except ImportError:      # pragma: no cover - circular-import guard
        pass
    fn = _VALUE_RANGES.get(kernel_name)
    if fn is None:
        return
    for pos, rng in fn(refs).items():
        if 0 <= pos < len(refs):
            refs[pos].value_range = rng


# ---------------------------------------------------------------------------
# The abstract interpreter
# ---------------------------------------------------------------------------


class _Ctx:
    """Per-pallas_call verification context: findings + access log."""

    def __init__(self, kernel: str, entry: str, scope: str):
        self.kernel = kernel
        self.entry = entry
        self.scope = scope
        self.findings: List[Finding] = []
        self.accesses: List[Access] = []
        self.order = 0
        self.suppress = 0       # >0 during the symbolic scan pre-pass

    def tick(self) -> int:
        self.order += 1
        return self.order

    def find(self, rule: str, message: str, severity: str = "error") -> None:
        if self.suppress:
            return
        self.findings.append(Finding(
            rule=rule, entry=self.entry, scope=self.scope,
            primitive=self.kernel, severity=severity,
            message=f"kernel {self.kernel}: {message}"))

    def access(self, ref: RefInfo, kind: str, guards, full: bool) -> None:
        if self.suppress:
            return
        self.accesses.append(Access(ref, kind, self.tick(), tuple(guards),
                                    full))


def _const_absval(val) -> AbsVal:
    arr = np.asarray(val)
    if arr.size == 0:
        return AbsVal.top()
    if arr.dtype.kind in "biu":
        return AbsVal.interval(float(arr.min()), float(arr.max()))
    if arr.dtype.kind == "f" and arr.size == 1 and np.isfinite(arr).all():
        return AbsVal.const(float(arr))
    return AbsVal.top()


class _Interp:
    def __init__(self, ctx: _Ctx, pid_syms: List[Sym]):
        self.ctx = ctx
        self.pid_syms = pid_syms

    # -- environment --------------------------------------------------------

    def _lookup(self, env: dict, atom):
        if hasattr(atom, "val"):                      # Literal
            return _const_absval(atom.val)
        return env.get(atom, AbsVal.top())

    def _abs(self, env: dict, atom) -> AbsVal:
        v = self._lookup(env, atom)
        return v if isinstance(v, AbsVal) else AbsVal.top()

    # -- indexers -----------------------------------------------------------

    def _index_entries(self, eqn, env, n_lead: int):
        """Yield (axis, kind, parts) per indexed dim of a get/swap eqn.

        kind is "int" (parts = AbsVal) or "slice"
        (parts = (start AbsVal, size, stride))."""
        tree = eqn.params.get("tree")
        idx_atoms = list(eqn.invars[n_lead:])
        if tree is None:
            return []
        indexers = tree_util.tree_unflatten(tree, idx_atoms)
        out = []
        axis = 0
        for indexer in (indexers if isinstance(indexers, tuple)
                        else (indexers,)):
            indices = getattr(indexer, "indices", None)
            if indices is None:                     # bare int/slice indexer
                indices = (indexer,)
            for ind in indices:
                if hasattr(ind, "start") and hasattr(ind, "size"):
                    start = (AbsVal.const(ind.start)
                             if isinstance(ind.start, (int, np.integer))
                             else self._abs(env, ind.start))
                    size = (int(ind.size)
                            if isinstance(ind.size, (int, np.integer))
                            else None)
                    stride = getattr(ind, "stride", 1)
                    stride = (int(stride)
                              if isinstance(stride, (int, np.integer)) else 1)
                    out.append((axis, "slice", (start, size, stride)))
                elif isinstance(ind, (int, np.integer)):
                    out.append((axis, "int", AbsVal.const(int(ind))))
                else:
                    out.append((axis, "int", self._abs(env, ind)))
                axis += 1
        return out

    def _check_access(self, eqn, env, ref: RefInfo, n_lead: int,
                      what: str) -> bool:
        """oob-access proof of one get/swap/addupdate; returns full-block."""
        entries = self._index_entries(eqn, env, n_lead)
        dims = ref.block_shape
        if not entries:           # x_ref[...] with no indexer tree: full
            return True
        full = len(entries) == len(dims)
        for axis, kind, parts in entries:
            if axis >= len(dims):
                break
            dim = int(dims[axis])
            if kind == "slice":
                start, size, stride = parts
                siv = start.iv()
                if size is None:          # dynamic size: require full proof
                    lo, hi = siv.lo, float("inf")
                else:
                    lo = siv.lo
                    hi = siv.hi + (size - 1) * stride
                full = full and start.is_const and siv.lo == 0 \
                    and size == dim and stride == 1
                if lo < 0 or hi > dim - 1:
                    rng = Interval(lo, hi)
                    self.ctx.find(
                        "oob-access",
                        f"{what} {ref.label} axis {axis}: slice "
                        f"[start + 0..{(size or 0) - 1}] spans "
                        f"{rng.render()}, outside the block's "
                        f"[0, {dim - 1}] (start range {siv.render()})")
            else:
                iv = parts.iv()
                full = full and dim == 1 and parts.is_const and iv.lo == 0
                if iv.lo < 0 or iv.hi > dim - 1:
                    self.ctx.find(
                        "oob-access",
                        f"{what} {ref.label} axis {axis}: index range "
                        f"{iv.render()} outside the block's [0, {dim - 1}]")
        return full

    # -- primitive handlers -------------------------------------------------

    def run(self, jaxpr, env: dict, guards: Tuple[tuple, ...] = ()):
        """Interpret a (closed) jaxpr body; returns abstract outvars."""
        consts = getattr(jaxpr, "consts", None)
        inner = getattr(jaxpr, "jaxpr", jaxpr)
        if consts is not None:
            for cv, c in zip(inner.constvars, consts):
                env[cv] = _const_absval(c)
        else:
            for cv in inner.constvars:
                env.setdefault(cv, AbsVal.top())
        for eqn in inner.eqns:
            self.eqn(eqn, env, guards)
        return [self._lookup(env, v) for v in inner.outvars]

    def _bind(self, jaxpr, vals) -> dict:
        inner = getattr(jaxpr, "jaxpr", jaxpr)
        return dict(zip(inner.invars, vals))

    def eqn(self, eqn, env: dict, guards) -> None:
        name = eqn.primitive.name
        handler = getattr(self, f"_p_{name}", None)
        if handler is not None:
            outs = handler(eqn, env, guards)
        elif name in _IDENTITY_PRIMS:
            outs = [self._abs(env, eqn.invars[0])]
        elif name in _JOIN_PRIMS:
            vals = [self._abs(env, v) for v in eqn.invars]
            out = vals[0]
            for v in vals[1:]:
                out = out.join(v)
            outs = [out]
        elif sub_jaxprs(eqn):
            outs = self._generic_call(eqn, env, guards)
        else:
            vals = [self._lookup(env, v) for v in eqn.invars]
            avs = [v for v in vals if isinstance(v, AbsVal)]
            meta = avs[0].meta(*avs[1:]) if avs else {}
            outs = [AbsVal.top(**meta)] * len(eqn.outvars)
        if outs is None:
            outs = []
        for v, out in zip(eqn.outvars, outs):
            env[v] = out

    # arithmetic ----------------------------------------------------------

    def _p_add(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        return [a.add(b)]

    def _p_sub(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        return [a.sub(b)]

    def _p_mul(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        return [a.mul(b)]

    def _p_neg(self, eqn, env, guards):
        return [self._abs(env, eqn.invars[0]).neg()]

    def _p_max(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        ia, ib = a.iv(), b.iv()
        return [AbsVal.interval(max(ia.lo, ib.lo), max(ia.hi, ib.hi),
                                **a.meta(b))]

    def _p_min(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        ia, ib = a.iv(), b.iv()
        return [AbsVal.interval(min(ia.lo, ib.lo), min(ia.hi, ib.hi),
                                **a.meta(b))]

    def _p_div(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        dt = getattr(getattr(eqn.outvars[0], "aval", None), "dtype", None)
        if dt is not None and np.dtype(dt).kind in "iu" and b.is_const \
                and b.iv().lo > 0 and a.iv().lo >= 0:
            return [AbsVal(base=a.iv().floordiv(b.iv().lo), **a.meta(b))]
        return [AbsVal.top(**a.meta(b))]

    def _p_rem(self, eqn, env, guards):
        a, b = (self._abs(env, v) for v in eqn.invars)
        if b.is_const and b.iv().lo > 0:
            n = b.iv().lo
            lo = 0.0 if a.iv().lo >= 0 else -(n - 1)
            return [AbsVal.interval(lo, n - 1, **a.meta(b))]
        return [AbsVal.top(**a.meta(b))]

    def _p_iota(self, eqn, env, guards):
        shape = eqn.params.get("shape", ())
        dim = eqn.params.get("dimension", 0)
        hi = (int(shape[dim]) - 1) if shape else 0
        return [AbsVal.interval(0, max(hi, 0))]

    # comparisons ---------------------------------------------------------

    def _cmp(self, eqn, env, decide):
        a, b = (self._abs(env, v) for v in eqn.invars)
        d = a.sub(b).iv()
        tri = decide(d)            # True / False / None
        if tri is None:
            out = AbsVal.interval(0, 1, **a.meta(b))
        else:
            out = AbsVal.const(1 if tri else 0).with_meta(**a.meta(b))
        return out, a, b

    def _p_lt(self, eqn, env, guards):
        out, _, _ = self._cmp(eqn, env, lambda d: True if d.hi < 0 else
                              (False if d.lo >= 0 else None))
        return [out]

    def _p_le(self, eqn, env, guards):
        out, _, _ = self._cmp(eqn, env, lambda d: True if d.hi <= 0 else
                              (False if d.lo > 0 else None))
        return [out]

    def _p_gt(self, eqn, env, guards):
        out, _, _ = self._cmp(eqn, env, lambda d: True if d.lo > 0 else
                              (False if d.hi <= 0 else None))
        return [out]

    def _p_ge(self, eqn, env, guards):
        out, _, _ = self._cmp(eqn, env, lambda d: True if d.lo >= 0 else
                              (False if d.hi < 0 else None))
        return [out]

    def _p_eq(self, eqn, env, guards):
        out, a, b = self._cmp(eqn, env, lambda d: True if (d.is_point and
                              d.lo == 0) else (False if (d.lo > 0 or
                                                         d.hi < 0) else None))
        pred = _pid_eq0_pred(a, b) or _pid_eq0_pred(b, a)
        if pred is not None:
            out = dataclasses.replace(out, pred=pred)
        return [out]

    def _p_ne(self, eqn, env, guards):
        out, _, _ = self._cmp(eqn, env, lambda d: False if (d.is_point and
                              d.lo == 0) else (True if (d.lo > 0 or
                                                        d.hi < 0) else None))
        return [out]

    def _p_select_n(self, eqn, env, guards):
        pred = self._abs(env, eqn.invars[0])
        cases = [self._abs(env, v) for v in eqn.invars[1:]]
        piv = pred.iv()
        if piv.is_point and 0 <= int(piv.lo) < len(cases):
            out = cases[int(piv.lo)]
        else:
            out = cases[0]
            for c in cases[1:]:
                out = out.join(c)
        meta = out.meta(pred)
        # a where() with a pad-clean predicate is THE sanctioned mask:
        # it launders the pad taint of its data operands.
        meta["pad"] = meta["pad"] if pred.pad else pred.pad
        return [dataclasses.replace(out, pred=None, **meta)]

    def _p_convert_element_type(self, eqn, env, guards):
        return [self._lookup(env, eqn.invars[0])
                if isinstance(self._lookup(env, eqn.invars[0]), AbsVal)
                else AbsVal.top()]

    def _p_reduce_sum(self, eqn, env, guards):
        a = self._abs(env, eqn.invars[0])
        n_in = int(np.prod(eqn.invars[0].aval.shape, dtype=np.int64)) \
            if getattr(eqn.invars[0], "aval", None) is not None else 1
        n_out = int(np.prod(eqn.outvars[0].aval.shape, dtype=np.int64)) \
            if getattr(eqn.outvars[0], "aval", None) is not None else 1
        factor = max(n_in // max(n_out, 1), 1)
        return [AbsVal(base=a.iv() * Interval(0, factor), **a.meta())
                if a.iv().lo >= 0 else
                AbsVal(base=a.iv().scale(factor), **a.meta())]

    def _p_argmax(self, eqn, env, guards):
        return self._arg_reduce(eqn, env)

    def _p_argmin(self, eqn, env, guards):
        return self._arg_reduce(eqn, env)

    def _arg_reduce(self, eqn, env):
        a = self._abs(env, eqn.invars[0])
        axes = eqn.params.get("axes", ())
        shape = getattr(getattr(eqn.invars[0], "aval", None), "shape", ())
        hi = 0
        for ax in axes:
            if ax < len(shape):
                hi = max(hi, int(shape[ax]) - 1)
        return [AbsVal.interval(0, hi, **a.meta())]

    # refs ----------------------------------------------------------------

    def _p_program_id(self, eqn, env, guards):
        axis = eqn.params.get("axis", 0)
        if axis >= len(self.pid_syms):
            return [AbsVal.interval(0, float("inf"))]
        return [AbsVal.of_sym(self.pid_syms[axis])]

    def _p_num_programs(self, eqn, env, guards):
        axis = eqn.params.get("axis", 0)
        if axis < len(self.pid_syms):
            rng = self.pid_syms[axis].range
            if rng.hi != float("inf"):
                return [AbsVal.const(rng.hi + 1)]
        return [AbsVal.interval(1, float("inf"))]

    def _p_get(self, eqn, env, guards):
        ref = self._lookup(env, eqn.invars[0])
        if not isinstance(ref, RefInfo):
            return [AbsVal.top()]
        full = self._check_access(eqn, env, ref, 1, "load from")
        self.ctx.access(ref, "read", guards, full)
        return [self._load_val(ref)]

    def _load_val(self, ref: RefInfo) -> AbsVal:
        base = ref.value_range if ref.value_range is not None else TOP
        pad = frozenset([ref.idx]) if ref.padded_axes else frozenset()
        return AbsVal(base=base, reads=frozenset([ref.idx]) | ref.stored_reads,
                      pad=pad | ref.stored_pad)

    def _p_swap(self, eqn, env, guards):
        ref = self._lookup(env, eqn.invars[0])
        if not isinstance(ref, RefInfo):
            return [AbsVal.top()]
        val = self._abs(env, eqn.invars[1])
        full = self._check_access(eqn, env, ref, 2, "store to")
        self._store(ref, val, guards, full)
        return [self._load_val(ref)]      # swap returns the old contents

    def _p_addupdate(self, eqn, env, guards):
        ref = self._lookup(env, eqn.invars[0])
        if not isinstance(ref, RefInfo):
            return []
        val = self._abs(env, eqn.invars[1])
        self._check_access(eqn, env, ref, 2, "accumulate into")
        # addupdate IS a read-modify-write by construction
        val = dataclasses.replace(val, reads=val.reads | {ref.idx})
        self._store(ref, val, guards, full=False)
        return []

    def _store(self, ref: RefInfo, val: AbsVal, guards, full: bool) -> None:
        is_accum = ref.idx in val.reads
        if val.pad and ref.kind == "out" and not self.ctx.suppress:
            srcs = ", ".join(f"operand {i}" for i in sorted(val.pad))
            self.ctx.find(
                "unmasked-pad",
                f"store to {ref.label} consumes data loaded from a "
                f"partial trailing block ({srcs}) without passing through "
                f"a where()/mask — padded lanes reach the output")
        self.ctx.access(ref, "accum" if is_accum else "write", guards, full)
        if not self.ctx.suppress:
            ref.stored_reads = ref.stored_reads | val.reads
            ref.stored_pad = ref.stored_pad | val.pad

    # control flow --------------------------------------------------------

    def _p_cond(self, eqn, env, guards):
        pred = self._abs(env, eqn.invars[0])
        branches = eqn.params.get("branches", ())
        operands = [self._lookup(env, v) for v in eqn.invars[1:]]
        piv = pred.iv()
        chosen = None
        if piv.is_point:
            i = min(max(int(piv.lo), 0), len(branches) - 1)
            chosen = [(i, guards)]
        else:
            chosen = []
            for i in range(len(branches)):
                if len(branches) == 2 and pred.pred is not None:
                    g = pred.pred if i == 1 else ("not",) + pred.pred
                else:
                    g = ("branch", i)
                chosen.append((i, guards + (g,)))
        outs = None
        for i, g in chosen:
            sub = branches[i]
            sub_env = self._bind(sub, operands)
            res = self.run(sub, sub_env, g)
            if outs is None:
                outs = res
            else:
                outs = [a.join(b) if isinstance(a, AbsVal) and
                        isinstance(b, AbsVal) else a
                        for a, b in zip(outs, res)]
        return outs or []

    def _p_scan(self, eqn, env, guards):
        p = eqn.params
        body = p["jaxpr"]
        nc, ncar = p["num_consts"], p["num_carry"]
        length = int(p.get("length", 0) or 0)
        consts = [self._lookup(env, v) for v in eqn.invars[:nc]]
        inits = [self._abs(env, v) for v in eqn.invars[nc:nc + ncar]]
        xs = [self._abs(env, v) for v in eqn.invars[nc + ncar:]]

        # pass 1 (symbolic, no findings): carries as fresh symbols, to
        # recognize induction carries (out = carry + loop-invariant stride)
        syms = [Sym.fresh(f"carry{i}", TOP, "carry") for i in range(ncar)]
        self.ctx.suppress += 1
        try:
            outs1 = self.run(body, self._bind(
                body, consts + [AbsVal.of_sym(s) for s in syms] + xs), guards)
        finally:
            self.ctx.suppress -= 1
        carry_outs = [o if isinstance(o, AbsVal) else AbsVal.top()
                      for o in outs1[:ncar]]

        iter_sym = Sym.fresh("iter", Interval(0, max(length - 1, 0)), "iter")
        in_loop: List[AbsVal] = []
        sym_set = set(syms)
        for s, init, out in zip(syms, inits, carry_outs):
            tm = out.term_map()
            coeff = tm.pop(s, 0.0)
            if coeff == 1.0 and not (sym_set & set(tm)):
                stride = AbsVal(base=out.base, terms=tuple(tm.items()),
                                reads=out.reads, pad=out.pad)
                in_loop.append(init.add(
                    stride.mul(AbsVal.of_sym(iter_sym))))
            else:
                # non-affine carry: widen (out was computed from TOP syms)
                in_loop.append(AbsVal(base=init.iv().join(out.iv()),
                                      **init.meta(out)))

        # pass 2 (real): findings + access log with the proven carry ranges
        outs2 = self.run(body, self._bind(body, consts + in_loop + xs),
                         guards)
        finals = []
        for init, out in zip(inits, outs2[:ncar]):
            o = out if isinstance(out, AbsVal) else AbsVal.top()
            finals.append(init.join(o) if length else init)
        ys = [o if isinstance(o, AbsVal) else AbsVal.top()
              for o in outs2[ncar:]]
        return finals + ys

    def _p_while(self, eqn, env, guards):
        p = eqn.params
        cn, bn = p.get("cond_nconsts", 0), p.get("body_nconsts", 0)
        body = p["body_jaxpr"]
        consts = [self._lookup(env, v) for v in eqn.invars[cn:cn + bn]]
        inits = [self._abs(env, v) for v in eqn.invars[cn + bn:]]
        # widen every carry to TOP (keeping taint): sound, may over-flag —
        # a traced-bound loop the analysis can't bound is worth a look
        carries = [AbsVal.top(**v.meta()) for v in inits]
        outs = self.run(body, self._bind(body, consts + carries), guards)
        return [i.join(o) if isinstance(o, AbsVal) else AbsVal.top()
                for i, o in zip(inits, outs)]

    def _generic_call(self, eqn, env, guards):
        subs = sub_jaxprs(eqn)
        vals = [self._lookup(env, v) for v in eqn.invars]
        outs = None
        for sub in subs:
            n_in = len(sub.invars)
            inner = vals[len(vals) - n_in:] if n_in <= len(vals) else \
                [AbsVal.top()] * (n_in - len(vals)) + vals
            res = self.run(sub, dict(zip(sub.invars, inner)), guards)
            n_out = min(len(res), len(eqn.outvars))
            if outs is None:
                outs = [AbsVal.top()] * len(eqn.outvars)
            for i in range(n_out):
                r = res[len(res) - n_out + i]
                if isinstance(r, AbsVal):
                    j = len(eqn.outvars) - n_out + i
                    outs[j] = r if outs[j].base.is_top else outs[j].join(r)
        return outs or [AbsVal.top()] * len(eqn.outvars)


#: element-range-preserving prims (result values ⊆ input values)
_IDENTITY_PRIMS = frozenset({
    "copy", "reshape", "broadcast_in_dim", "squeeze", "expand_dims",
    "slice", "transpose", "rev", "reduce_max", "reduce_min", "reduce_and",
    "reduce_or", "stop_gradient", "abs_after", "dynamic_slice",
})

#: joins of all inputs
_JOIN_PRIMS = frozenset({"concatenate", "dynamic_update_slice", "pad",
                         "gather", "clamp"})


def _pid_eq0_pred(a: AbsVal, b: AbsVal):
    if (len(a.terms) == 1 and a.terms[0][1] == 1.0
            and a.terms[0][0].kind == "pid"
            and a.base.is_point and a.base.lo == 0
            and b.is_const and b.iv().lo == 0):
        return ("pid_eq0", a.terms[0][0].axis)
    return None


# ---------------------------------------------------------------------------
# Per-pallas_call verification
# ---------------------------------------------------------------------------


def _index_map_used_axes(index_map_jaxpr, n_axes: int) -> set:
    """Grid axes the block index map actually depends on (backward slice)."""
    jaxpr = getattr(index_map_jaxpr, "jaxpr", index_map_jaxpr)
    needed = {v for v in jaxpr.outvars if not hasattr(v, "val")}
    for eqn in reversed(jaxpr.eqns):
        if any(v in needed for v in eqn.outvars):
            needed.update(v for v in eqn.invars if not hasattr(v, "val"))
    return {i for i, v in enumerate(jaxpr.invars[:n_axes]) if v in needed}


def _build_refs(body, gm) -> List[RefInfo]:
    n_idx = getattr(gm, "num_index_operands", 0)
    nin = gm.num_inputs
    nout = gm.num_outputs
    bms = list(gm.block_mappings)
    refs: List[RefInfo] = []
    for i, invar in enumerate(body.invars):
        aval = getattr(invar, "aval", None)
        shape = tuple(int(d) for d in getattr(aval, "shape", ()))
        dtype = getattr(aval, "dtype", np.float32)
        if i < n_idx:
            kind, arr_shape, padded = "index", shape, ()
        elif i < n_idx + nin + nout:
            kind = "in" if i < n_idx + nin else "out"
            bm = bms[i - n_idx] if i - n_idx < len(bms) else None
            arr_shape = tuple(bm.array_shape_dtype.shape) if bm is not None \
                else shape
            padded = tuple(
                ax for ax, (b, d) in enumerate(zip(bm.block_shape, arr_shape))
                if isinstance(b, (int, np.integer)) and int(b) > 0
                and d % int(b)) if bm is not None else ()
        else:
            kind, arr_shape, padded = "scratch", shape, ()
        refs.append(RefInfo(idx=i, kind=kind, block_shape=shape,
                            array_shape=arr_shape, dtype=dtype,
                            padded_axes=padded))
    return refs


def _race_findings(ctx: _Ctx, refs: List[RefInfo], gm) -> None:
    grid = tuple(getattr(gm, "grid", ()) or ())
    n_idx = getattr(gm, "num_index_operands", 0)
    bms = list(gm.block_mappings)
    for ref in refs:
        if ref.kind != "out":
            continue
        bm = bms[ref.idx - n_idx] if ref.idx - n_idx < len(bms) else None
        if bm is None:
            continue
        used = _index_map_used_axes(bm.index_map_jaxpr, len(grid))
        revisited = [ax for ax, extent in enumerate(grid)
                     if ax not in used
                     and (not isinstance(extent, (int, np.integer))
                          or int(extent) > 1)]
        if not revisited:
            continue
        accs = [a for a in ctx.accesses if a.ref is ref]
        accums = [a for a in accs if a.kind == "accum"]
        inits = [a for a in accs if a.kind == "write" and a.full_block
                 and _is_init_guard(a.guards, revisited)]
        plains = [a for a in accs if a.kind == "write"
                  and not _is_init_guard(a.guards, revisited)]
        reads = [a for a in accs if a.kind == "read"]
        axes = ",".join(str(a) for a in revisited)
        if accums and not inits:
            ctx.find(
                "grid-race",
                f"output {ref.label} is accumulated across grid steps "
                f"(axis {axes} revisited by the index map) with no "
                f"pl.when(program_id == 0) full-block init store — the "
                f"read-modify-write reads uninitialized VMEM on the first "
                f"visit")
        elif accums and inits and reads and \
                min(i.order for i in inits) > min(r.order for r in reads):
            ctx.find(
                "grid-race",
                f"output {ref.label}: the pl.when init store does not "
                f"dominate the first read-modify-write (init is staged "
                f"after the accumulating read)")
        if plains:
            ctx.find(
                "grid-race",
                f"output {ref.label} is overwritten from multiple grid "
                f"steps (axis {axes} revisited) by a store outside the "
                f"pl.when(program_id == 0) init — cross-step race, the "
                f"last visiting step wins")


def _scratch_findings(ctx: _Ctx, refs: List[RefInfo], gm,
                      backend: str) -> None:
    scratch = [r for r in refs if r.kind == "scratch"]
    if not scratch:
        return
    scratch_bytes = sum(block_bytes(r.block_shape, r.dtype) for r in scratch)
    blocks = [(bm.block_shape, bm.array_shape_dtype.dtype)
              for bm in gm.block_mappings]
    total = estimate_vmem_bytes(blocks) + scratch_bytes
    budget = vmem_budget(backend)
    if total > budget:
        ctx.find(
            "scratch-overflow",
            f"scratch buffers add {scratch_bytes} bytes; the per-grid-step "
            f"working set is {total} bytes, over the {backend} lint budget "
            f"of {budget} bytes")


def verify_pallas_eqn(eqn, scope: str = "", entry: str = "",
                      backend: str = "tpu") -> List[Finding]:
    """Run the kernel-body rule families over one staged ``pallas_call``."""
    gm = eqn.params.get("grid_mapping")
    body = eqn.params.get("jaxpr")
    kernel = str(eqn.params.get("name_and_src_info", "pallas_call"))
    kernel = kernel.split(" ")[0]
    ctx = _Ctx(kernel, entry, scope)
    if gm is None or body is None:    # pragma: no cover - jax API drift
        ctx.findings.append(Finding(
            rule="oob-access", entry=entry, scope=scope, primitive=kernel,
            severity="warning",
            message=f"kernel {kernel}: pallas_call without grid_mapping/"
                    f"jaxpr params; cannot verify the body (jax API drift?)"))
        return ctx.findings
    body = getattr(body, "jaxpr", body)
    grid = tuple(getattr(gm, "grid", ()) or ())
    refs = _build_refs(body, gm)
    _apply_provenance(kernel, refs)
    pid_syms = [
        Sym.fresh(f"pid{ax}",
                  Interval(0, int(extent) - 1)
                  if isinstance(extent, (int, np.integer)) else TOP,
                  "pid", axis=ax)
        for ax, extent in enumerate(grid)]
    interp = _Interp(ctx, pid_syms)
    env = dict(zip(body.invars, refs))
    try:
        interp.run(body, env)
    except Exception as e:            # pragma: no cover - keep CI diagnosable
        ctx.findings.append(Finding(
            rule="oob-access", entry=entry, scope=scope, primitive=kernel,
            severity="warning",
            message=f"kernel {kernel}: body interpretation failed "
                    f"({type(e).__name__}: {e}); bounds not proven"))
        return ctx.findings
    _race_findings(ctx, refs, gm)
    _scratch_findings(ctx, refs, gm, backend)
    return ctx.findings


def rule_kernel_body(closed_jaxpr, entry: str = "",
                     backend: str = "tpu") -> List[Finding]:
    """Verify every ``pallas_call`` staged by a traced entrypoint.

    The kernel-body companion to ``pallas-resource``: where that rule
    checks the call's BlockSpecs from outside, this one proves the body's
    Ref accesses in-bounds, its cross-grid-step writes race-free, its
    padded loads masked, and its scratch within the VMEM budget."""
    out: List[Finding] = []
    for eqn, path, _ in iter_eqns(closed_jaxpr, into_pallas=False):
        if eqn.primitive.name != "pallas_call":
            continue
        out.extend(verify_pallas_eqn(eqn, scope=path, entry=entry,
                                     backend=backend))
    return out
