"""Sparsity-invariant linting of traced entrypoints.

Library API
-----------
* :func:`lint_fn` — trace any callable with :func:`jax.make_jaxpr` and
  run the jaxpr rule pack over it.
* :func:`lint_config` — lint the named architecture's real entrypoints
  (decode step, paged decode step, fused prefill, the kwta→packed-
  projection kernel pipeline, forward training loss) abstractly: params
  and caches are
  :func:`jax.eval_shape` pytrees, so even the full-scale configs lint on
  a CPU without allocating a single weight.  The decode step is
  additionally AOT-compiled and its HLO text checked (host transfers,
  unexpected collectives).
* :func:`expected_selects` — the Select-count model: mirrors the exact
  dispatch logic of :func:`repro.core.layers.apply_kwta` /
  :func:`repro.core.layers.packed_linear_apply` to predict how many
  ``top_k`` primitives each sparse layer should stage (paper Fig. 8a:
  at most one per layer).
* :func:`lint_kernels` — sweep the Pallas kernel registry
  (:mod:`repro.kernels.registry`) and run the kernel-body verifier
  (:mod:`repro.analysis.kernel_rules`) plus the resource rule over every
  shipped kernel at every declared shape configuration (the CLI
  ``--kernels`` path).
* :func:`seeded_regressions` — deliberately broken pipelines (a doubled
  Select; an f64 kernel input; an off-by-one ``pl.ds`` gather; a missing
  ``pl.when`` accumulation init) used by the CLI ``--self-test`` and the
  test suite to prove the linter catches what it claims to.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import SparsityConfig, choose_executor, choose_path
from repro.core.masks import pad_to_multiple

from .findings import Finding, Report
from .hlo_rules import rule_hlo_collectives, rule_hlo_host_transfer
from .rules import (rule_dense_fallback, rule_dtype_promotion,
                    rule_pallas_resource, rule_select_count)

ENTRIES = ("decode", "decode_paged", "prefill", "kernel", "train")


# ---------------------------------------------------------------------------
# The Select-count model
# ---------------------------------------------------------------------------

def family_path(sp: SparsityConfig, n_tokens: int, d_in: int,
                d_out: int) -> Optional[str]:
    """Execution path the packed projection consuming the k-WTA output
    will take, or None when the projection isn't CS-packed."""
    if not (sp.weight_sparse and d_in % sp.n == 0 and d_out % sp.n == 0):
        return None
    d_in_p = pad_to_multiple(d_in, sp.n)
    return choose_path(sp, n_tokens, d_in_p, x_is_sparse=sp.activation_sparse)


def family_selects(sp: SparsityConfig, n_tokens: int, d_in: int,
                   d_out: int) -> int:
    """Selects staged by one kwta→packed-projection pipeline.

    Mirrors ``apply_kwta`` + ``packed_linear_apply``: the k-WTA stages a
    ``top_k`` unless it runs the histogram/bisection datapath; the
    downstream projection re-derives the support (one more ``top_k``)
    only on the topk path when no ``(vals, idx)`` handoff exists — the
    handoff exists only for the exact global top-k impl."""
    if not sp.activation_sparse:
        return 0
    k = sp.k_for(d_in)
    if k >= d_in:
        return 0
    kwta_runs_topk = sp.kwta_impl not in ("hist", "bisect")
    has_support = kwta_runs_topk and sp.kwta_partitions <= 1
    n_sel = 1 if kwta_runs_topk else 0
    if family_path(sp, n_tokens, d_in, d_out) == "topk" and not has_support:
        n_sel += 1
    return n_sel


def expected_selects(cfg, n_tokens: int) -> Optional[Dict[str, int]]:
    """Per-layer-key Select expectation for a model config, or None when
    the config is un-modeled (MoE routers run their own top-k)."""
    if cfg.is_moe:
        return None
    exp: Dict[str, int] = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind not in ("attn", "shared_attn"):
            continue
        if cfg.d_ff > 0:
            exp[f"b{i}_{kind}/ffn"] = family_selects(
                cfg.ffn_sparsity, n_tokens, cfg.d_ff, cfg.d_model)
        if cfg.proj_sparsity.activation_sparse:
            exp[f"b{i}_{kind}/o_proj"] = family_selects(
                cfg.proj_sparsity, n_tokens,
                cfg.padded_heads * cfg.head_dim, cfg.d_model)
    return exp


def _wants_dense_fallback_rule(cfg, n_tokens: int) -> bool:
    """The dense-fallback rule only means something when a sparse family
    is configured to hit the Pallas topk path: in the Hadamard/dense
    regimes a ``dot_general`` on the k-sparse activation IS the
    sanctioned algorithm."""
    if cfg.is_moe:
        # The MoE router's own top-k legitimately feeds dense expert
        # combines; taint can't tell it from the sparse-sparse support.
        return False
    fams = [(cfg.ffn_sparsity, cfg.d_ff, cfg.d_model),
            (cfg.proj_sparsity, cfg.padded_heads * cfg.head_dim,
             cfg.d_model)]
    for sp, d_in, d_out in fams:
        if not (sp.activation_sparse and d_in):
            continue
        if not choose_executor(sp).use_pallas:
            continue
        if family_path(sp, n_tokens, d_in, d_out) == "topk":
            return True
    return False


# ---------------------------------------------------------------------------
# lint_fn: the library core
# ---------------------------------------------------------------------------

def lint_fn(fn: Callable, *example_args,
            entry: str = "fn",
            expected: Optional[Dict[str, int]] = None,
            check_select: bool = True,
            check_dense_fallback: bool = False,
            check_dtype: bool = True,
            check_pallas: bool = True,
            check_kernel_body: bool = True,
            backend: str = "tpu",
            waivers: Sequence[str] = (),
            **example_kwargs) -> Report:
    """Trace ``fn`` on abstract arguments and lint the jaxpr.

    ``example_args`` may be concrete arrays or ``ShapeDtypeStruct``
    pytrees (e.g. from :func:`jax.eval_shape`) — tracing never executes
    the function.  Returns a :class:`Report`; ``report.ok`` is the
    one-line "zero findings" assertion."""
    closed = jax.make_jaxpr(lambda *a, **k: fn(*a, **k))(
        *example_args, **example_kwargs)
    report = Report(entries=[entry])
    if check_select:
        report.add(rule_select_count(closed, expected, entry), waivers)
    if check_dense_fallback:
        report.add(rule_dense_fallback(closed, entry), waivers)
    if check_dtype:
        report.add(rule_dtype_promotion(closed, entry), waivers)
    if check_pallas:
        report.add(rule_pallas_resource(closed, entry, backend), waivers)
    if check_kernel_body:
        from .kernel_rules import rule_kernel_body
        report.add(rule_kernel_body(closed, entry=entry, backend=backend),
                   waivers)
    return report


def lint_hlo(hlo_text: str, entry: str = "decode",
             allowed_collectives: Sequence[str] = (),
             waivers: Sequence[str] = ()) -> Report:
    """Run the HLO rule pack over compiled module text."""
    report = Report(entries=[f"{entry}:hlo"])
    report.add(rule_hlo_host_transfer(hlo_text, entry), waivers)
    report.add(rule_hlo_collectives(hlo_text, entry, allowed_collectives),
               waivers)
    return report


# ---------------------------------------------------------------------------
# lint_config: lint a named architecture's real entrypoints
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _decode_batch(cfg, slots: int):
    if cfg.frontend == "embed":
        return {"embeds": _sds((slots, 1, cfg.d_model), jnp.float32)}
    return {"tokens": _sds((slots, 1), jnp.int32)}


def _seq_batch(cfg, batch: int, seq: int, labels: bool):
    out = {}
    if cfg.frontend == "embed":
        out["embeds"] = _sds((batch, seq, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = _sds((batch, seq), jnp.int32)
    if cfg.frontend == "vision_prefix":
        out["patch_embeds"] = _sds((batch, cfg.n_prefix, cfg.d_model),
                                   jnp.float32)
    if labels:
        out["labels"] = _sds((batch, seq), jnp.int32)
    return out


def _with_pallas_mode(cfg, mode: Optional[str]):
    if mode is None:
        return cfg
    return dataclasses.replace(
        cfg,
        ffn_sparsity=dataclasses.replace(cfg.ffn_sparsity, use_pallas=mode),
        proj_sparsity=dataclasses.replace(cfg.proj_sparsity,
                                          use_pallas=mode))


def lint_config(arch, entries: Sequence[str] = ENTRIES,
                use_pallas: Optional[str] = "force",
                slots: int = 4, seq: int = 8, max_seq: int = 64,
                reduced: bool = False,
                check_hlo: bool = True,
                backend: str = "tpu",
                waivers: Sequence[str] = ()) -> Report:
    """Lint the named (or given) model config's entrypoints abstractly.

    ``arch`` is a config name (``smollm_360m``) or a ``ModelConfig``.
    ``use_pallas`` overrides both sparsity families' backend flag
    (default ``"force"``: lint the Pallas kernel path even on CPU, which
    is exactly what the CI job wants); ``None`` keeps the config's own.
    ``check_hlo`` AOT-compiles the decode step and runs the HLO rules
    (single-process: the rules prove no collectives/host transfers leak
    into an unsharded decode)."""
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config(arch) if isinstance(arch, str) else arch
    if reduced:
        cfg = cfg.reduced()
    cfg = _with_pallas_mode(cfg, use_pallas)

    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: T.init_model(key, cfg)[0])
    report = Report()

    if "decode" in entries:
        cache = jax.eval_shape(lambda: T.init_cache(cfg, slots, max_seq)[0])
        batch = _decode_batch(cfg, slots)
        pos = _sds((slots,), jnp.int32)
        fn = lambda p, c, b, q: T.serve_step(p, c, b, q, cfg)
        exp = expected_selects(cfg, n_tokens=slots)
        report.extend(lint_fn(
            fn, params, cache, batch, pos, entry="decode", expected=exp,
            check_dense_fallback=_wants_dense_fallback_rule(cfg, slots),
            backend=backend, waivers=waivers))
        if check_hlo:
            hlo = jax.jit(fn).lower(params, cache, batch, pos)\
                .compile().as_text()
            report.extend(lint_hlo(hlo, entry="decode", waivers=waivers))

    if "decode_paged" in entries and all(
            k in ("attn", "shared_attn") for k in cfg.block_pattern):
        # Same decode step through the paged KV pools: the gather/scatter
        # indirection must not stage extra Selects, promote dtypes, or
        # (HLO) introduce host transfers — the page tables stay on device.
        from repro.runtime.kvcache import PagedKV
        geo = PagedKV.build(max_seq, slots, page_size=16)
        cache = jax.eval_shape(lambda: T.init_paged_cache(
            cfg, geo.n_pages, geo.page_size)[0])
        batch = _decode_batch(cfg, slots)
        pos = _sds((slots,), jnp.int32)
        pages = _sds((slots, geo.blocks_per_slot), jnp.int32)
        fn = lambda p, c, b, q, pg: T.serve_step(p, c, b, q, cfg, pages=pg)
        exp = expected_selects(cfg, n_tokens=slots)
        report.extend(lint_fn(
            fn, params, cache, batch, pos, pages, entry="decode_paged",
            expected=exp,
            check_dense_fallback=_wants_dense_fallback_rule(cfg, slots),
            backend=backend, waivers=waivers))
        if check_hlo:
            hlo = jax.jit(fn).lower(params, cache, batch, pos, pages)\
                .compile().as_text()
            report.extend(lint_hlo(hlo, entry="decode_paged",
                                   waivers=waivers))

    if "prefill" in entries and T.supports_fused_prefill(cfg):
        batch = _seq_batch(cfg, 1, seq, labels=False)
        fn = lambda p, b: T.prefill(p, b, cfg, max_seq)
        exp = expected_selects(cfg, n_tokens=seq)
        report.extend(lint_fn(
            fn, params, batch, entry="prefill", expected=exp,
            check_dense_fallback=_wants_dense_fallback_rule(cfg, seq),
            backend=backend, waivers=waivers))

    if "kernel" in entries and cfg.d_ff > 0:
        report.extend(lint_kernel_pipeline(
            cfg.ffn_sparsity, slots, cfg.d_ff, cfg.d_model,
            backend=backend, waivers=waivers))

    if "train" in entries:
        batch = _seq_batch(cfg, 2, seq, labels=True)
        fn = lambda p, b: T.loss_fn(p, b, cfg)[0]
        exp = expected_selects(cfg, n_tokens=2 * seq)
        report.extend(lint_fn(
            fn, params, batch, entry="train", expected=exp,
            check_dense_fallback=False,   # backward re-plays are not linted
            backend=backend, waivers=waivers))
    return report


def lint_kernel_pipeline(sp: SparsityConfig, n_tokens: int, d_in: int,
                         d_out: int, backend: str = "tpu",
                         waivers: Sequence[str] = ()) -> Report:
    """Lint the bare kwta→packed-projection pipeline (the
    ``cs_topk_matmul`` entrypoint) at the given shapes."""
    from repro.core.layers import (apply_kwta, packed_linear_apply,
                                   packed_linear_init)
    if not (sp.weight_sparse and d_in % sp.n == 0 and d_out % sp.n == 0):
        return Report(entries=["kernel:skipped"])
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: packed_linear_init(
        key, d_in, d_out, sp, bias=False)[0])
    x = _sds((n_tokens, d_in), jnp.float32)

    def fn(p, x):
        with jax.named_scope("ffn_kwta"):
            h, support = apply_kwta(x, sp, return_support=True)
        with jax.named_scope("ffn_down"):
            return packed_linear_apply(p, h, sp,
                                       x_is_sparse=sp.activation_sparse,
                                       support=support)

    expected = {"ffn": family_selects(sp, n_tokens, d_in, d_out)}
    on_topk = (sp.activation_sparse and choose_executor(sp).use_pallas
               and family_path(sp, n_tokens, d_in, d_out) == "topk")
    return lint_fn(fn, params, x, entry="kernel", expected=expected,
                   check_dense_fallback=on_topk, backend=backend,
                   waivers=waivers)


# ---------------------------------------------------------------------------
# lint_kernels: sweep the Pallas kernel registry
# ---------------------------------------------------------------------------

def lint_kernels(backend: str = "tpu",
                 waivers: Sequence[str] = ()) -> Report:
    """Verify every registered Pallas kernel at every declared shape.

    Stages each :func:`repro.kernels.registry.kernel_cases` entry
    abstractly and runs the kernel-body rule families (``oob-access``,
    ``grid-race``, ``unmasked-pad``, ``scratch-overflow``) plus the
    outer ``pallas-resource`` rule over it — the CLI ``--kernels`` /
    CI sweep."""
    from repro.kernels.registry import kernel_cases

    from .kernel_rules import rule_kernel_body

    report = Report()
    for case in kernel_cases():
        entry = f"kernels:{case.label}"
        closed = case.trace()
        report.entries.append(entry)
        report.add(rule_kernel_body(closed, entry=entry, backend=backend),
                   waivers)
        report.add(rule_pallas_resource(closed, entry, backend), waivers)
    return report


# ---------------------------------------------------------------------------
# Seeded regressions (CLI --self-test; tests/test_analysis.py)
# ---------------------------------------------------------------------------

def _regression_double_topk() -> Report:
    """A layer that ignores the k-WTA support handoff and re-derives it:
    two Selects where the paper's pipeline (Fig. 8a) stages one."""
    from repro.core.layers import (apply_kwta, packed_linear_apply,
                                   packed_linear_init)
    sp = SparsityConfig(n=4, k_frac=0.125, route_share=0, kwta_impl="topk")
    d_in, d_out, tokens = 128, 64, 2
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: packed_linear_init(
        key, d_in, d_out, sp, bias=False)[0])
    x = _sds((tokens, d_in), jnp.float32)

    def bad(p, x):
        with jax.named_scope("b0_attn"):
            with jax.named_scope("ffn_kwta"):
                h, support = apply_kwta(x, sp, return_support=True)
            with jax.named_scope("ffn_down"):
                # BUG under test: drop the handoff; the projection
                # re-runs lax.top_k on the already k-sparse activation.
                return packed_linear_apply(p, h, sp, x_is_sparse=True,
                                           support=None)

    expected = {"b0_attn/ffn": family_selects(sp, tokens, d_in, d_out)}
    return lint_fn(bad, params, x, entry="decode", expected=expected,
                   check_pallas=False)


def _regression_f64_kernel() -> Report:
    """An f64 constant leaking into the sparse contraction: every value
    it touches promotes to float64 (only stageable under x64)."""
    from jax.experimental import enable_x64

    from repro.core.functional import cs_topk_from_support, topk_support_flat

    with enable_x64():
        packed = _sds((16, 8, 4), jnp.float32)
        route = _sds((16, 8, 4), jnp.int32)
        x = _sds((2, 32), jnp.float32)

        def bad(x, packed, route):
            with jax.named_scope("b0_attn"):
                with jax.named_scope("ffn_down"):
                    with jax.named_scope("cs_topk"):
                        vals, sel = topk_support_flat(x, 4)
                        # BUG under test: a float64 scale drags the whole
                        # kernel input up to 64-bit.
                        vals = vals * jnp.asarray(1.0, jnp.float64)
                        return cs_topk_from_support(
                            vals, sel // 4, sel % 4, packed, route)

        return lint_fn(bad, x, packed, route, entry="kernel",
                       check_select=False, check_pallas=False)


def _regression_oob_gather() -> Report:
    """The off-by-one ``pl.ds`` gather: the ``fori_loop`` body fetches
    packed row ``p + 1`` — one past the declared ``[0, P)`` provenance
    range of ``p_idx``, so the last partition reads out of bounds."""
    import functools

    from jax import lax
    from jax.experimental import pallas as pl

    from .intervals import Interval
    from .kernel_rules import register_value_ranges

    b, k, p, g, n = 2, 8, 16, 4, 4

    def _oob_gather_kernel(vals_ref, pidx_ref, packed_ref, o_ref, *, k_nnz):
        vals, pidx = vals_ref[0], pidx_ref[0]
        bg, nn = packed_ref.shape[1], packed_ref.shape[2]

        def body(j, acc):
            # BUG under test: rows are fetched at p + 1, sailing one past
            # the end of the packed partition dim when p == P - 1.
            w = packed_ref[pl.ds(pidx[j] + 1, 1), :, :][0]
            return acc + w * vals[j]

        acc = lax.fori_loop(0, k_nnz, body, jnp.zeros((bg, nn), jnp.float32))
        o_ref[0] = acc.reshape(bg * nn)

    # same provenance the real topk_gather kernel declares: p_idx ∈ [0, P)
    register_value_ranges(
        "_oob_gather_kernel",
        lambda refs: {1: Interval(0, refs[2].block_shape[0] - 1)})

    def bad(vals, pidx, packed):
        return pl.pallas_call(
            functools.partial(_oob_gather_kernel, k_nnz=k),
            grid=(1, b),
            in_specs=[pl.BlockSpec((1, k), lambda ig, ib: (ib, 0)),
                      pl.BlockSpec((1, k), lambda ig, ib: (ib, 0)),
                      pl.BlockSpec((p, g, n), lambda ig, ib: (0, 0, 0))],
            out_specs=pl.BlockSpec((1, g * n), lambda ig, ib: (ib, 0)),
            out_shape=jax.ShapeDtypeStruct((b, g * n), jnp.float32),
        )(vals, pidx, packed)

    return lint_fn(bad, _sds((b, k), jnp.float32), _sds((b, k), jnp.int32),
                   _sds((p, g, n), jnp.float32), entry="kernel",
                   check_select=False)


def _regression_missing_init() -> Report:
    """A grouped accumulation kernel whose ``pl.when(k == 0)`` zero-store
    was dropped: the ``+=`` reads uninitialized VMEM on the first visit
    of every revisited output block."""
    from jax.experimental import pallas as pl

    def _missing_init_kernel(x_ref, w_ref, o_ref):
        # BUG under test: no @pl.when(pl.program_id(3) == 0) init before
        # the read-modify-write on the k-revisited output block.
        o_ref[0] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    def bad(xg, packed):
        return pl.pallas_call(
            _missing_init_kernel,
            grid=(2, 1, 1, 2),
            in_specs=[
                pl.BlockSpec((1, 8, 8), lambda s, ib, ig, ik: (s, ib, ik)),
                pl.BlockSpec((1, 8, 8), lambda s, ib, ig, ik: (s, ik, ig)),
            ],
            out_specs=pl.BlockSpec((1, 8, 8),
                                   lambda s, ib, ig, ik: (s, ib, ig)),
            out_shape=jax.ShapeDtypeStruct((2, 8, 8), jnp.float32),
        )(xg, packed)

    return lint_fn(bad, _sds((2, 8, 16), jnp.float32),
                   _sds((2, 16, 8), jnp.float32), entry="kernel",
                   check_select=False)


def seeded_regressions() -> Dict[str, Callable[[], Report]]:
    """Named deliberately-broken pipelines the linter must flag."""
    return {"double-topk": _regression_double_topk,
            "f64-kernel": _regression_f64_kernel,
            "oob-gather": _regression_oob_gather,
            "missing-init": _regression_missing_init}


def self_test() -> List[str]:
    """Run every seeded regression; return failure descriptions (empty
    when the linter caught all of them — the CI negative test)."""
    expect_rule = {"double-topk": "select-count",
                   "f64-kernel": "dtype-promotion",
                   "oob-gather": "oob-access",
                   "missing-init": "grid-race"}
    # kernel-body findings must name the kernel AND the offending Ref
    expect_text = {"oob-gather": ("_oob_gather_kernel", "in[2]"),
                   "missing-init": ("_missing_init_kernel", "out[2]")}
    failures = []
    for name, run in seeded_regressions().items():
        report = run()
        rule = expect_rule[name]
        hits = report.by_rule(rule)
        if not hits:
            failures.append(
                f"seeded regression {name!r} was NOT caught (expected a "
                f"{rule} finding; got: {report.render()})")
            continue
        for needle in expect_text.get(name, ()):
            if not any(needle in f.message for f in hits):
                failures.append(
                    f"seeded regression {name!r}: the {rule} finding does "
                    f"not name {needle!r} (got: {hits[0].message})")
    return failures
