"""CLI: lint a named config's entrypoints for sparsity invariants.

    python -m repro.analysis --config smollm_360m --fail-on-findings
    python -m repro.analysis --kernels --fail-on-findings
    python -m repro.analysis --self-test          # CI negative test

``--kernels`` sweeps the Pallas kernel registry and runs the
kernel-body verifier (bounds, race, masking, scratch proofs) over every
shipped kernel at every declared shape configuration; it composes with
``--config`` (both reports merge into one exit status).

Exit codes: 0 clean (or all seeded regressions caught under
``--self-test``); 1 findings present (or a regression slipped through);
2 usage error.
"""

from __future__ import annotations

import argparse
import sys


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Sparsity-invariant linter: prove the sparse-sparse "
                    "path stays sparse (one Select per layer, Pallas "
                    "consumes the support, no f64, BlockSpecs fit VMEM, "
                    "decode stays on-device).")
    p.add_argument("--config", help="architecture config name "
                   "(e.g. smollm_360m); see repro.configs.list_archs()")
    p.add_argument("--kernels", action="store_true",
                   help="sweep the Pallas kernel registry with the "
                   "kernel-body verifier (oob-access, grid-race, "
                   "unmasked-pad, scratch-overflow) across all declared "
                   "shape configs")
    p.add_argument("--entries",
                   default="decode,decode_paged,prefill,kernel,train",
                   help="comma-separated entrypoints to lint "
                   "(default: all)")
    p.add_argument("--use-pallas", default="force",
                   choices=["auto", "force", "off", "config"],
                   help="override the config's Pallas mode while linting "
                   "('force' checks the kernel path even on CPU; "
                   "'config' keeps the config's own)")
    p.add_argument("--slots", type=int, default=4,
                   help="decode batch slots (default 4)")
    p.add_argument("--seq", type=int, default=8,
                   help="prefill/train sequence length (default 8)")
    p.add_argument("--reduced", action="store_true",
                   help="lint the reduced() smoke-test config instead of "
                   "the full-scale one")
    p.add_argument("--no-hlo", action="store_true",
                   help="skip AOT-compiling the decode step for the HLO "
                   "rule pack (faster)")
    p.add_argument("--waive", action="append", default=[],
                   metavar="RULE[:SCOPE]",
                   help="waive findings of RULE (optionally restricted "
                   "to a name-stack scope prefix); repeatable")
    p.add_argument("--json", action="store_true",
                   help="emit the report as JSON")
    p.add_argument("--fail-on-findings", action="store_true",
                   help="exit 1 when findings remain (default behavior; "
                   "kept explicit for CI readability)")
    p.add_argument("--self-test", action="store_true",
                   help="run the seeded regressions and exit 0 only if "
                   "the linter catches all of them")
    p.add_argument("--seed-regression", metavar="NAME",
                   choices=["double-topk", "f64-kernel", "oob-gather",
                            "missing-init"],
                   help="lint the named deliberately-broken pipeline and "
                   "exit by its findings (demonstrates the non-zero exit)")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.analysis import (lint_config, lint_kernels,
                                seeded_regressions, self_test)

    if args.seed_regression:
        report = seeded_regressions()[args.seed_regression]()
        print(report.to_json() if args.json else report.render())
        return 0 if report.ok else 1

    if args.self_test:
        failures = self_test()
        if failures:
            for f in failures:
                print(f, file=sys.stderr)
            return 1
        print("self-test: all seeded regressions caught")
        return 0

    if not (args.config or args.kernels):
        print("error: --config and/or --kernels is required "
              "(or use --self-test)", file=sys.stderr)
        return 2

    from repro.analysis import Report
    report = Report()
    if args.kernels:
        report.extend(lint_kernels(waivers=tuple(args.waive)))
    if args.config:
        entries = tuple(e.strip() for e in args.entries.split(",")
                        if e.strip())
        mode = None if args.use_pallas == "config" else args.use_pallas
        report.extend(lint_config(
            args.config, entries=entries, use_pallas=mode, slots=args.slots,
            seq=args.seq, reduced=args.reduced, check_hlo=not args.no_hlo,
            waivers=tuple(args.waive)))
    print(report.to_json() if args.json else report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
