"""Checkpoint substrate: sharded, atomic, resharding-capable."""

from .ckpt import (latest_step, list_steps, prune, restore, restore_latest,
                   save, save_async)

__all__ = ["latest_step", "list_steps", "prune", "restore", "restore_latest",
           "save", "save_async"]
