"""Sharded, atomic, resharding-capable checkpoints (no orbax offline).

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, step, config
        shard_p0.npz       # this process's addressable leaf shards
    <dir>/step_000123.done # commit marker (atomic rename publishes it)

Properties:
  * **Atomic**: writes go to ``step_X.tmp`` and are renamed; a crash
    mid-write leaves no half-valid checkpoint (restore only trusts dirs
    with the ``.done`` marker).
  * **Sharded**: each process saves only its addressable shards (one file
    per process; single-process covers the CPU container, the same code
    path fans out per-host on a real cluster).
  * **Resharding restore**: arrays are restored through
    ``jax.make_array_from_callback`` against the *target* sharding, which
    may come from a different mesh shape than the save — this is the
    elastic-scaling path (checkpoint on 256 devices, resume on 128).
  * **Async**: ``save_async`` snapshots to host memory synchronously (so
    donated buffers are safe) and writes to disk on a background thread.
  * **Integrity**: per-leaf checksums (crc of raw bytes) in the manifest.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keys = [f"leaf_{i:05d}" for i in range(len(leaves))]
    return dict(zip(keys, leaves)), treedef


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         process_index: int = 0) -> str:
    """Synchronous checkpoint write. Returns the committed path."""
    leaves, treedef = _flatten(tree)
    host = {k: np.asarray(v) for k, v in leaves.items()}
    return _write(directory, step, host, treedef, extra, process_index)


def save_async(directory: str, step: int, tree,
               extra: Optional[Dict] = None,
               process_index: int = 0) -> threading.Thread:
    """Snapshot to host now; write to disk in the background."""
    leaves, treedef = _flatten(tree)
    host = {k: np.asarray(v) for k, v in leaves.items()}  # device->host copy

    t = threading.Thread(
        target=_write, args=(directory, step, host, treedef, extra,
                             process_index), daemon=True)
    t.start()
    return t


def _write(directory, step, host, treedef, extra, process_index) -> str:
    final = _step_dir(directory, step)
    # unique tmp dir per writer: concurrent saves of the same step (e.g. a
    # periodic async save racing the final sync save) must not collide
    tmp = f"{final}.tmp.{os.getpid()}.{threading.get_ident()}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                   for k, v in host.items()},
        "extra": extra or {},
    }
    np.savez(os.path.join(tmp, f"shard_p{process_index}.npz"), **host)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.isdir(final):
        shutil.rmtree(final)
    try:
        os.replace(tmp, final)
    except OSError:
        # a concurrent writer committed this step first — accept theirs
        shutil.rmtree(tmp, ignore_errors=True)
        if not os.path.exists(final + ".done"):
            raise
        return final
    # commit marker — restore only trusts checkpoints that have it
    with open(final + ".done", "w") as f:
        f.write("ok")
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name + ".done")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like_tree,
            shardings=None, process_index: int = 0,
            strict_checksum: bool = True):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedShardings (matching like_tree) to
    place each leaf — pass the *current* mesh's shardings to reshard an
    old checkpoint onto a different topology (elastic restart).
    """
    path = _step_dir(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_p{process_index}.npz"))
    leaves_like, treedef = jax.tree.flatten(like_tree)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        key = f"leaf_{i:05d}"
        arr = data[key]
        meta = manifest["leaves"][key]
        if strict_checksum:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {like.shape}")
        if sh is not None:
            arr = jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: a[idx])
        else:
            arr = jax.numpy.asarray(arr, dtype=like.dtype)
        out.append(arr)
    return treedef.unflatten(out), manifest["extra"]


def restore_latest(directory: str, like_tree, shardings=None, **kw):
    step = latest_step(directory)
    if step is None:
        return None, None, None
    tree, extra = restore(directory, step, like_tree, shardings, **kw)
    return step, tree, extra


def prune(directory: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (garbage collection)."""
    steps = list_steps(directory)
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
        try:
            os.remove(_step_dir(directory, s) + ".done")
        except OSError:
            pass
