"""Batched serving driver: prefill + decode with KV caches.

Serves a (reduced or full) LM with continuous batched greedy decoding:
  1. prefill the prompt batch (full forward, cache write via teacher
     forcing of the prompt tokens),
  2. decode tokens one position at a time with ``serve_step``.

The prefill here reuses the decode step position-by-position for cache
construction on CPU-sized models (exact, simple); the 32k-prefill cell in
the dry-run lowers the fused full-sequence forward instead.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.sharding import make_rules, param_sharding, use_rules


class Server:
    def __init__(self, cfg, mesh, max_seq: int):
        self.cfg = cfg
        self.mesh = mesh
        self.max_seq = max_seq
        self.rules = make_rules(mesh, "decode")
        with use_rules(self.rules):
            params, specs = T.init_model(jax.random.PRNGKey(0), cfg)
            self.p_shard = param_sharding(specs, params, self.rules)
            self.params = jax.device_put(params, self.p_shard)
        self._step = jax.jit(
            lambda p, c, b, pos: T.serve_step(p, c, b, pos, cfg),
            donate_argnums=(1,), static_argnums=())

    def new_cache(self, batch: int):
        with use_rules(self.rules):
            cache, specs = T.init_cache(self.cfg, batch, self.max_seq)
            shard = param_sharding(specs, cache, self.rules)
            return jax.device_put(cache, shard)

    def generate(self, prompts: np.ndarray, gen_len: int):
        """prompts: (B, P) int32. Greedy decode ``gen_len`` tokens."""
        b, p_len = prompts.shape
        cache = self.new_cache(b)
        with use_rules(self.rules):
            # prefill by stepping through prompt positions (cache build)
            tok = prompts[:, :1].astype(np.int32)
            logits = None
            for pos in range(p_len):
                batch = {"tokens": jnp.asarray(prompts[:, pos:pos + 1])}
                logits, cache = self._step(self.params, cache, batch, pos)
            out = []
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for i in range(gen_len):
                out.append(np.asarray(cur))
                logits, cache = self._step(self.params, cache,
                                           {"tokens": cur}, p_len + i)
                cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))
    server = Server(cfg, mesh, max_seq=args.prompt_len + args.gen + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    total = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched); sample: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
