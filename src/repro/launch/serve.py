"""Continuous-batching inference engine: fused prefill + slot decode.

The serving subsystem the paper's throughput claim lands on: weight
sparsity (CS-packed projections) and activation sparsity (k-WTA) both cut
per-token decode cost, and the batched-decode regime is where the two
multiply (cf. arXiv 2311.07625) — so the engine's job is to keep the
decode batch full.

Architecture:

  * ``Engine`` owns a fixed pool of ``n_slots`` KV-cache slots (the decode
    batch) plus the compiled functions:
      - *fused prefill* — ONE compiled call per prompt
        (:func:`repro.models.transformer.prefill`): full-sequence forward
        that writes the prompt's KV rows in bulk, compiled once per
        power-of-two prompt bucket;
      - *slot insert* — scatters a prefilled single-request cache fragment
        into the live batch cache at a traced slot index;
      - *decode step* — one token for ALL slots per call, with per-slot
        positions ((B,) vector ``pos``), so requests at different depths
        share every matmul.
  * ``repro.runtime.scheduler.Scheduler`` owns policy: FIFO admission into
    free slots mid-flight, retirement on token budget / EOS, and greedy or
    temperature/top-k sampling on host.

``Engine.serve(requests)`` runs the loop: admit -> prefill -> insert ->
decode-all-slots -> sample -> retire, until queue and slots drain.  Slots
freed by short requests are refilled immediately, which is why continuous
batching beats the static batch whenever lengths are mixed (and ties it
when lengths are uniform).

``Engine.generate_static`` keeps the old static-batch greedy path
(stepwise prefill through the decode kernel) as the correctness oracle the
parity tests compare against.

Paged KV cache (ISSUE 9): ``Engine(kv_layout="paged")`` swaps the
``(n_slots, max_seq)`` contiguous cache for a pool of fixed-size pages
(:mod:`repro.runtime.kvcache`) — prompts prefill in page-aligned chunks
interleaved with decode steps (one chunk per loop iteration, bounding the
ITL spike in-flight requests see when a long prompt lands), decode
reads/writes through per-slot page tables threaded into the jit, and
retirement returns pages copy-free.  Token-exact vs the contiguous
layout (greedy), which stays the default and the parity oracle.

Grow-on-demand chains (ISSUE 10): ``kv_policy="grow"`` (the paged
default) admits on the PROMPT footprint only and grows each chain one
page at a time as decode crosses page boundaries; when the pool runs
dry the youngest-admitted slot is preempted (recompute-on-resume) so
concurrency no longer pays every request's worst case up front.
Requests sharing a prompt prefix share physical pages (hash-matched at
admit) with copy-on-write on first divergent write.
``kv_policy="reserve"`` keeps the ISSUE 9 reserve-on-admit behaviour as
the scheduling oracle.

Telemetry (ISSUE 8): pass ``telemetry=repro.obs.Telemetry.on(...)`` and
the engine traces spans around every stage (``schedule.admit`` /
``prefill`` / ``insert`` / ``decode.step`` / ``sample``), samples
queue-depth and slot-occupancy gauges each step, keeps per-request
lifecycle records (scheduler-side), attributes the staged execution
paths (``repro.core.api.observe_dispatch``), and — every
``telemetry.sparsity_every`` steps — decodes through a *probed* twin of
the step jit whose extra outputs are the per-layer k-WTA winner sets, so
realized activation sparsity and cross-step winner overlap are measured
from what actually ran.  ``Engine.metrics_snapshot()`` returns the whole
picture as a JSON-ready dict, live or at end of run.  With the default
``telemetry=None`` everything degrades to null objects and the staged
step program is bit-identical to the un-instrumented one.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --slots 4 --requests 8 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import os
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs import get_config
from repro.core.api import observe_dispatch
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.obs import DispatchStats, SparsityStats, Telemetry
from repro.obs import sparsity as obs_sparsity
from repro.runtime.kvcache import (NULL_PAGE, BlockAllocator, PagedKV,
                                   prefix_keys)
from repro.runtime.scheduler import (Request, SamplingParams, Scheduler,
                                     sample_token)
from repro.sharding import make_rules, param_sharding, use_rules


def _bucket(n: int, max_seq: int) -> int:
    """Next power-of-two prompt bucket (>= 8) so prefill compiles once per
    bucket, not once per prompt length."""
    b = 8
    while b < n:
        b *= 2
    return min(b, max_seq)


class Engine:
    """Continuous-batching server for one model on one mesh.

    ``use_pallas`` overrides the kernel-executor flag on BOTH sparsity
    families (cfg.ffn_sparsity / cfg.proj_sparsity): 'auto' (Pallas on TPU
    only), 'force' (everywhere, interpret fallback off-TPU) or 'off' (pure
    jnp).  With the sparse-sparse config this is what routes the decode
    batch through the batched ``topk_gather`` kernel — one launch per
    sparse layer per decode step."""

    def __init__(self, cfg, mesh, max_seq: int, n_slots: int = 4,
                 params=None, use_pallas: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None,
                 kv_layout: str = "contiguous", page_size: int = 16,
                 n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 kv_policy: str = "grow"):
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"kv_layout must be 'contiguous' or 'paged', "
                             f"got {kv_layout!r}")
        if kv_policy not in ("reserve", "grow"):
            raise ValueError(f"kv_policy must be 'reserve' or 'grow', "
                             f"got {kv_policy!r}")
        if use_pallas is not None:
            cfg = dataclasses.replace(
                cfg,
                ffn_sparsity=dataclasses.replace(
                    cfg.ffn_sparsity, use_pallas=use_pallas),
                proj_sparsity=dataclasses.replace(
                    cfg.proj_sparsity, use_pallas=use_pallas))
        self.cfg = cfg
        self.mesh = mesh
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.rules = make_rules(mesh, "decode")
        with use_rules(self.rules):
            if params is None:
                params, specs = T.init_model(jax.random.PRNGKey(0), cfg)
                p_shard = param_sharding(specs, params, self.rules)
                params = jax.device_put(params, p_shard)
            self.params = params
        self._step = jax.jit(
            lambda p, c, b, pos: T.serve_step(p, c, b, pos, cfg),
            donate_argnums=(1,))
        # jit's shape-keyed cache compiles this once per prompt *bucket*
        # (prompts are padded to power-of-two lengths), not per prompt
        self._prefill_jit = jax.jit(
            lambda p, toks: T.prefill(p, {"tokens": toks}, cfg, max_seq))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.prefill_calls = 0  # one per admitted prompt (tests assert)
        # -- paged KV layout --------------------------------------------------
        self.kv_layout = kv_layout
        self.kv_policy = kv_policy
        self.kv_geo: Optional[PagedKV] = None
        if kv_layout == "paged":
            self.kv_geo = PagedKV.build(max_seq, n_slots,
                                        page_size=page_size,
                                        n_pages=n_pages)
            # page-aligned chunk bucket: long prompts prefill in slabs of
            # this many rows, one slab per serve-loop iteration; the true
            # chunk length rides in as a traced scalar, so every chunk
            # shares ONE compile.
            self.prefill_chunk = (prefill_chunk if prefill_chunk is not None
                                  else min(4 * self.kv_geo.page_size,
                                           self.kv_geo.view_len))
            self.kv_geo.chunk_spans(1, self.prefill_chunk)  # validates
            self._step_paged = jax.jit(
                lambda p, c, b, pos, pg: T.serve_step(p, c, b, pos, cfg,
                                                      pages=pg),
                donate_argnums=(1,))
            self._chunk_jit = jax.jit(
                lambda p, c, toks, pg, start, ln: T.prefill_chunk(
                    p, c, {"tokens": toks}, start, ln, cfg, pg),
                donate_argnums=(1,))
            # copy-on-write break: clone page src's rows onto dst in every
            # pool leaf (traced ids -> one compile, reused for every CoW)
            self._copy_page_jit = jax.jit(
                lambda c, src, dst: T.copy_cache_page(c, src, dst),
                donate_argnums=(0,))

            def _probed_step_paged(p, c, b, pos, pg):
                with obs_sparsity.capture_supports() as cap:
                    logits, new_cache = T.serve_step(p, c, b, pos, cfg,
                                                     pages=pg)
                self._sparsity_meta.update(cap.meta)
                return logits, new_cache, cap.take_arrays()

            self._step_paged_probed = jax.jit(_probed_step_paged,
                                              donate_argnums=(1,))
        # -- telemetry ------------------------------------------------------
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.off()
        self._sparsity = SparsityStats(self.telemetry.registry)
        self._dispatch = DispatchStats()
        self._last_sched: Optional[Scheduler] = None
        #: label -> {"d", "kind"} for the probed step's captured layers,
        #: filled at the probed jit's (one) trace.
        self._sparsity_meta: dict = {}

        def _probed_step(p, c, b, pos):
            # Twin of self._step that also returns the per-layer winner
            # sets: the capture is active while serve_step TRACES, so the
            # supports (already computed by apply_kwta) leave the scan as
            # stacked extra outputs — no second top_k, no host callback.
            with obs_sparsity.capture_supports() as cap:
                logits, new_cache = T.serve_step(p, c, b, pos, cfg)
            self._sparsity_meta.update(cap.meta)
            return logits, new_cache, cap.take_arrays()

        self._step_probed = jax.jit(_probed_step, donate_argnums=(1,))

    # -- compiled pieces ----------------------------------------------------
    @staticmethod
    def _insert_impl(cache, frag, slot):
        """Scatter a (n_units, 1, ...) prefill fragment into the
        (n_units, n_slots, ...) batch cache at batch row ``slot``."""
        def ins(c, f):
            starts = (0, slot) + (0,) * (c.ndim - 2)
            return lax.dynamic_update_slice(c, f.astype(c.dtype), starts)
        return jax.tree.map(ins, cache, frag)

    def new_cache(self, batch: int):
        with use_rules(self.rules):
            cache, specs = T.init_cache(self.cfg, batch, self.max_seq)
            shard = param_sharding(specs, cache, self.rules)
            return jax.device_put(cache, shard)

    def new_paged_cache(self):
        """The page pools (``kv_layout='paged'``): leaves shaped
        (n_units, n_pages, page_size, ...), addressed through per-slot
        page tables instead of batch rows."""
        geo = self.kv_geo
        with use_rules(self.rules):
            cache, specs = T.init_paged_cache(self.cfg, geo.n_pages,
                                              geo.page_size)
            shard = param_sharding(specs, cache, self.rules)
            return jax.device_put(cache, shard)

    def _prefill(self, prompt: Sequence[int]):
        """One fused-prefill call. Returns (last-position logits (vocab,),
        cache fragment sized (n_units, 1, max_seq, ...)).

        Rejects prompts longer than ``max_seq`` here, at the boundary:
        ``_bucket`` clamps to ``max_seq``, so an oversized prompt reaching
        it would be silently truncated to a partial prefix (``serve()``
        validates too, but direct callers must not depend on that)."""
        p_len = len(prompt)
        if p_len > self.max_seq:
            raise ValueError(
                f"prompt length {p_len} exceeds max_seq {self.max_seq}; "
                "refusing to truncate")
        bucket = _bucket(p_len, self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :p_len] = np.asarray(prompt, np.int32)
        logits, frag = self._prefill_jit(self.params, jnp.asarray(toks))
        self.prefill_calls += 1
        self.telemetry.registry.counter("serve.prefill_calls").inc()
        return np.asarray(logits[0, p_len - 1]), frag

    # -- continuous-batching loop -------------------------------------------
    def serve(self, requests: Sequence[Request]):
        """Run every request to completion with continuous batching.

        Returns (outputs, stats): outputs maps request uid -> generated
        token list; stats has tok/s, time-to-first-token per request, and
        decode-step/prefill-call counts.

        With ``kv_layout='paged'`` the same loop runs over the page-pool
        cache: admission reserves KV pages, prompts prefill in
        page-aligned chunks interleaved with decode steps, and retirement
        releases pages copy-free (see :meth:`_serve_paged`).
        """
        if not T.supports_fused_prefill(self.cfg):
            raise NotImplementedError(
                f"{self.cfg.name}: block pattern {self.cfg.block_pattern} "
                "has no fused prefill; serve with generate_static")
        for r in requests:
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.uid}: max_new_tokens must "
                                 "be >= 1 (the first token comes from "
                                 "prefill)")
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.uid}: prompt must hold at "
                                 "least one token (the first sampled "
                                 "token conditions on it)")
            if len(r.prompt) + r.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request {r.uid}: prompt {len(r.prompt)} + "
                    f"max_new {r.max_new_tokens} exceeds max_seq "
                    f"{self.max_seq}")
        if self.kv_layout == "paged":
            return self._serve_paged(requests)
        tel = self.telemetry
        tracer = tel.tracer
        reg = tel.registry
        g_queue = reg.gauge("serve.queue_depth")
        g_active = reg.gauge("serve.slots_active")
        g_occ = reg.gauge("serve.slot_occupancy")
        h_prefill = reg.histogram("serve.prefill_s")
        h_step = reg.histogram("serve.decode_step_s")
        h_step_recent = reg.rolling_histogram("serve.decode_step_recent_s")
        c_steps = reg.counter("serve.decode_steps")
        probe_every = tel.sparsity_every if tel.enabled else 0
        sched = Scheduler(self.n_slots, telemetry=tel)
        self._last_sched = sched
        sched.submit_many(requests, now=0.0)
        with use_rules(self.rules):
            cache = self.new_cache(self.n_slots)
            tokens = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            n_steps = 0
            t0 = time.perf_counter()
            while sched.has_work:
                with tracer.span("schedule.admit"):
                    admitted = sched.admit(now=time.perf_counter() - t0)
                for slot in admitted:
                    req = slot.request
                    self._sparsity.reset_row(slot.index)
                    t_pre = time.perf_counter()
                    with tracer.span("prefill", uid=req.uid,
                                     prompt_len=len(req.prompt)):
                        row, frag = self._prefill(req.prompt)
                        with tracer.span("insert"):
                            cache = self._insert(cache, frag,
                                                 jnp.int32(slot.index))
                    h_prefill.observe(time.perf_counter() - t_pre)
                    with tracer.span("sample"):
                        first = sample_token(row, req.sampling, slot.rng)
                    sched.record_token(slot, first,
                                       now=time.perf_counter() - t0)
                    tokens[slot.index, 0] = first
                    pos[slot.index] = slot.pos  # == len(prompt)
                # budget-1 requests finish at prefill
                sched.retire_done(now=time.perf_counter() - t0)
                active = sched.active_slots()
                g_queue.set(len(sched.queue))
                g_active.set(len(active))
                g_occ.set(len(active) / self.n_slots)
                if not active:
                    continue
                # The dispatch observer rides the FIRST decode-step trace
                # only (sealed after), so path attribution describes one
                # staged step, not one per retrace.
                obs_ctx = (observe_dispatch(self._dispatch.on_event)
                           if tel.enabled and not self._dispatch.sealed
                           else contextlib.nullcontext())
                probed = probe_every > 0 and n_steps % probe_every == 0
                t_step = time.perf_counter()
                with tracer.span("decode.step", probed=probed), obs_ctx:
                    step_in = ({"tokens": jnp.asarray(tokens)},
                               jnp.asarray(pos))
                    if probed:
                        logits, cache, sp_aux = self._step_probed(
                            self.params, cache, *step_in)
                    else:
                        logits, cache = self._step(self.params, cache,
                                                   *step_in)
                    logits = np.asarray(logits)
                self._dispatch.seal()
                dt_step = time.perf_counter() - t_step
                h_step.observe(dt_step)
                h_step_recent.observe(dt_step)
                c_steps.inc()
                n_steps += 1
                if probed:
                    self._sparsity.update(
                        sp_aux, self._sparsity_meta,
                        active_rows=[s.index for s in active])
                now = time.perf_counter() - t0
                with tracer.span("sample"):
                    for slot in active:
                        nxt = sample_token(logits[slot.index],
                                           slot.request.sampling, slot.rng)
                        sched.record_token(slot, nxt, now=now)
                        tokens[slot.index, 0] = nxt
                        slot.pos += 1
                        pos[slot.index] = slot.pos
                sched.retire_done(now=time.perf_counter() - t0)
            dt = time.perf_counter() - t0
        total = sum(len(v) for v in sched.finished.values())
        stats = {
            "wall_s": dt,
            "tok_s": total / dt if dt else float("inf"),
            "decode_steps": n_steps,
            "prefill_calls": self.prefill_calls,
            "ttft_s": dict(sched.ttft),
        }
        if tel.enabled:
            tel.emit({"kind": "snapshot",
                      "metrics": self.metrics_snapshot()})
        return sched.finished, stats

    # -- paged serve loop -----------------------------------------------------
    def _serve_paged(self, requests: Sequence[Request]):
        """Paged serve loop: admit-by-pages -> chunked prefill (one chunk
        per iteration, interleaved with decode) -> decode through the
        page tables -> retire (copy-free page reclamation).

        Differences from the contiguous loop:

        * Admission is gated on FREE PAGES, not just free slots.  Under
          ``kv_policy="reserve"`` the queue head reserves
          ``ceil((prompt + max_new) / page_size)`` pages at admit, so
          decode can never run out mid-request.  Under ``"grow"`` (the
          default) it takes only its PROMPT pages — minus any prefix
          pages adopted from the allocator's hash index — and decode
          pages arrive lazily: each iteration extends every decoding
          slot's chain (oldest-admitted first) to cover its next write,
          preempting the youngest-admitted slot when the pool is dry
          (recompute-on-resume; pre-validation of every request's
          worst case against the whole pool makes a sole survivor
          always able to finish, so eviction cannot livelock).
        * Writes into a page held by more than one chain break the
          sharing first: the allocator swaps in a private page and one
          compiled ``copy_page`` call clones the rows device-side
          (copy-on-write), so prefix sharing never changes any
          request's tokens.
        * A long prompt no longer stalls in-flight decode for its whole
          prefill: each iteration forwards at most ONE page-aligned
          chunk of the oldest prefilling slot, then decodes the slots
          whose prompts are fully cached — bounding the inter-token
          latency spike other requests see at admission
          (benchmarks/bench_serve.py measures the p95).
        * The decode step receives the per-slot page tables; rows of
          slots that are free or still prefilling are nulled for the
          step, so their (ignored) writes sink into the null page
          instead of a live chain.

        ``REPRO_KV_CHECK=1`` runs ``alloc.check()`` every loop iteration
        (instead of only on drain) — the paranoid mode the fuzz harness
        and the CI paged-smoke step serve under.
        """
        geo = self.kv_geo
        alloc = BlockAllocator(geo.n_pages, geo.page_size)
        for r in requests:
            need = alloc.pages_needed(len(r.prompt) + r.max_new_tokens)
            if need > alloc.capacity:
                raise ValueError(
                    f"request {r.uid}: needs {need} KV pages, pool holds "
                    f"{alloc.capacity} — raise n_pages")
        grow = self.kv_policy == "grow"
        paranoid = os.environ.get("REPRO_KV_CHECK") == "1"
        tel = self.telemetry
        tracer = tel.tracer
        reg = tel.registry
        g_queue = reg.gauge("serve.queue_depth")
        g_active = reg.gauge("serve.slots_active")
        g_occ = reg.gauge("serve.slot_occupancy")
        h_chunk = reg.histogram("serve.prefill_chunk_s")
        h_step = reg.histogram("serve.decode_step_s")
        h_step_recent = reg.rolling_histogram("serve.decode_step_recent_s")
        c_steps = reg.counter("serve.decode_steps")
        c_chunks = reg.counter("serve.prefill_chunks")
        c_cow = reg.counter("serve.cow_copies")
        c_grow = reg.counter("serve.kv_grow_pages")
        probe_every = tel.sparsity_every if tel.enabled else 0
        sched = Scheduler(self.n_slots, telemetry=tel, allocator=alloc,
                          kv_policy=self.kv_policy)
        self._last_sched = sched
        sched.submit_many(requests, now=0.0)
        tables = geo.empty_tables(self.n_slots)
        chunk = self.prefill_chunk
        ps = geo.page_size
        n_chunks = 0
        n_cow = 0
        n_cow_inplace = 0
        n_grown = 0
        max_concurrent = 0
        prefillq: "deque" = deque()  # slots mid-prompt, FIFO

        def _evict(victim):
            """Preempt ``victim``: null its page table, drop it from the
            prefill queue, hand the request back to the scheduler
            (pages released, request re-queued at the head)."""
            geo.clear_chain(tables, victim.index)
            if victim in prefillq:
                prefillq.remove(victim)
            sched.preempt(victim, now=time.perf_counter() - t0)

        def _ensure_free(n, requester):
            """Free >= ``n`` pages by preempting youngest-admitted slots
            (least service lost, FIFO order preserved on requeue).
            Returns False when ``requester`` itself was the victim —
            the caller's slot is gone and its work this iteration is
            abandoned."""
            while alloc.free_pages < n:
                victim = sched.preemption_victim()
                if victim is None:
                    raise RuntimeError(
                        "KV pool exhausted with no slot to preempt")
                _evict(victim)
                if victim is requester:
                    return False
            return True

        def _cow(slot, blk):
            """Break sharing of chain page ``blk`` before ``slot``
            writes there.  Returns False when the slot lost its chain
            while freeing a page for the copy."""
            nonlocal cache, n_cow, n_cow_inplace
            uid = slot.request.uid
            if not alloc.page_shared(uid, blk):
                return True
            if alloc.free_pages < 1 and not _ensure_free(1, slot):
                return False
            cow = alloc.cow_page(uid, blk)
            if cow is None:
                # _ensure_free just preempted the page's only co-holder
                # (the youngest slot is typically the prefix-adopter):
                # the page is uniquely held now — write in place, no copy
                n_cow_inplace += 1
                return True
            old, new = cow
            with tracer.span("kv.cow", uid=uid, block=blk):
                cache = self._copy_page_jit(cache, jnp.int32(old),
                                            jnp.int32(new))
            geo.set_chain(tables, slot.index, alloc.chain(uid))
            n_cow += 1
            c_cow.inc()
            return True

        with use_rules(self.rules):
            cache = self.new_paged_cache()
            tokens = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            n_steps = 0
            t0 = time.perf_counter()
            while sched.has_work:
                if paranoid:
                    alloc.check()
                with tracer.span("schedule.admit"):
                    admitted = sched.admit(now=time.perf_counter() - t0,
                                           chunked=True)
                for slot in admitted:
                    self._sparsity.reset_row(slot.index)
                    geo.set_chain(tables, slot.index,
                                  alloc.chain(slot.request.uid))
                    prefillq.append(slot)
                max_concurrent = max(max_concurrent,
                                     len(sched.active_slots()))
                # ONE chunk per iteration: prefill progress is interleaved
                # with decode so in-flight slots keep emitting tokens.
                if prefillq:
                    slot = prefillq[0]
                    req = slot.request
                    start = slot.prefill_pos
                    ln = min(chunk, len(req.prompt) - start)
                    # chunk rows may land in adopted prefix pages (an
                    # exact-duplicate prompt re-prefills its final token
                    # into the sharer's last page): break the sharing
                    # first.  _cow can preempt, including this very
                    # slot — then skip the chunk, the request is back in
                    # the queue.
                    ok = True
                    if grow:
                        for blk in range(start // ps,
                                         (start + ln - 1) // ps + 1):
                            if not _cow(slot, blk):
                                ok = False
                                break
                    if ok:
                        buf = np.zeros((1, chunk), np.int32)
                        buf[0, :ln] = np.asarray(
                            req.prompt[start:start + ln], np.int32)
                        t_pre = time.perf_counter()
                        with tracer.span("prefill.chunk", uid=req.uid,
                                         start=start, chunk_len=ln):
                            logits, cache = self._chunk_jit(
                                self.params, cache, jnp.asarray(buf),
                                jnp.asarray(
                                    tables[slot.index:slot.index + 1]),
                                jnp.int32(start), jnp.int32(ln))
                        h_chunk.observe(time.perf_counter() - t_pre)
                        c_chunks.inc()
                        n_chunks += 1
                        slot.prefill_pos += ln
                    if ok and not slot.prefilling:  # last chunk
                        prefillq.popleft()
                        self.prefill_calls += 1
                        reg.counter("serve.prefill_calls").inc()
                        if grow:
                            # rows are on device now — publish the
                            # prompt's pages for later prefix matches
                            alloc.register_chain_prefix(
                                req.uid, prefix_keys(req.prompt, ps))
                        row = np.asarray(logits[0, ln - 1])
                        with tracer.span("sample"):
                            first = sample_token(row, req.sampling,
                                                 slot.rng)
                        sched.record_token(slot, first,
                                           now=time.perf_counter() - t0)
                        tokens[slot.index, 0] = first
                        pos[slot.index] = slot.pos  # == len(prompt)
                # budget-1 requests finish at prefill
                for slot in sched.retire_done(now=time.perf_counter() - t0):
                    geo.clear_chain(tables, slot.index)
                if grow:
                    # grow every decoding slot's chain to cover its next
                    # write, oldest-admitted first (the youngest is the
                    # preemption victim, so growing oldest-first means a
                    # victim's freed pages go to the slots that keep
                    # running).  A slot evicted by an earlier _ensure_free
                    # in this very loop shows up as not busy — skip it.
                    for slot in sorted(sched.decoding_slots(),
                                       key=lambda s: s.admit_seq):
                        if not slot.busy:
                            continue
                        uid = slot.request.uid
                        evicted = False
                        while alloc.chain_len(uid) <= slot.pos // ps:
                            if alloc.free_pages < 1 \
                                    and not _ensure_free(1, slot):
                                evicted = True
                                break
                            alloc.extend(uid, 1)
                            n_grown += 1
                            c_grow.inc()
                        if evicted or not slot.busy:
                            continue
                        # the write row may sit in a page adopted from a
                        # prompt-prefix match: break the sharing first
                        if not _cow(slot, slot.pos // ps):
                            continue
                        geo.set_chain(tables, slot.index, alloc.chain(uid))
                active = sched.decoding_slots()
                g_queue.set(len(sched.queue))
                g_active.set(len(active))
                g_occ.set(len(active) / self.n_slots)
                if not active:
                    continue
                # Null the page-table rows of slots sitting this step out
                # (free, or mid-prefill): their stale token/pos rows still
                # ride the batch, but their writes sink to the null page.
                step_tables = tables.copy()
                decoding = {s.index for s in active}
                for i in range(self.n_slots):
                    if i not in decoding:
                        step_tables[i, :] = NULL_PAGE
                obs_ctx = (observe_dispatch(self._dispatch.on_event)
                           if tel.enabled and not self._dispatch.sealed
                           else contextlib.nullcontext())
                probed = probe_every > 0 and n_steps % probe_every == 0
                t_step = time.perf_counter()
                with tracer.span("decode.step", probed=probed), obs_ctx:
                    step_in = ({"tokens": jnp.asarray(tokens)},
                               jnp.asarray(pos), jnp.asarray(step_tables))
                    if probed:
                        logits, cache, sp_aux = self._step_paged_probed(
                            self.params, cache, *step_in)
                    else:
                        logits, cache = self._step_paged(
                            self.params, cache, *step_in)
                    logits = np.asarray(logits)
                self._dispatch.seal()
                dt_step = time.perf_counter() - t_step
                h_step.observe(dt_step)
                h_step_recent.observe(dt_step)
                c_steps.inc()
                n_steps += 1
                if probed:
                    self._sparsity.update(
                        sp_aux, self._sparsity_meta,
                        active_rows=[s.index for s in active])
                now = time.perf_counter() - t0
                with tracer.span("sample"):
                    for slot in active:
                        nxt = sample_token(logits[slot.index],
                                           slot.request.sampling, slot.rng)
                        sched.record_token(slot, nxt, now=now)
                        tokens[slot.index, 0] = nxt
                        slot.pos += 1
                        pos[slot.index] = slot.pos
                for slot in sched.retire_done(now=time.perf_counter() - t0):
                    geo.clear_chain(tables, slot.index)
            dt = time.perf_counter() - t0
        alloc.check()
        if alloc.used_pages:
            raise RuntimeError(f"{alloc.used_pages} KV pages still held "
                               "after the queue drained")
        total = sum(len(v) for v in sched.finished.values())
        stats = {
            "wall_s": dt,
            "tok_s": total / dt if dt else float("inf"),
            "decode_steps": n_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": n_chunks,
            "pages_capacity": alloc.capacity,
            "page_size": geo.page_size,
            "kv_policy": self.kv_policy,
            "max_concurrent": max_concurrent,
            "preemptions": sched.preemption_count,
            "prefix_hit_pages": sched.prefix_hit_pages,
            "cow_copies": n_cow,
            "cow_in_place": n_cow_inplace,
            "grown_pages": n_grown,
            "ttft_s": dict(sched.ttft),
        }
        if tel.enabled:
            tel.emit({"kind": "snapshot",
                      "metrics": self.metrics_snapshot()})
        return sched.finished, stats

    # -- telemetry read side -------------------------------------------------
    def metrics_snapshot(self) -> dict:
        """JSON-ready snapshot of everything the telemetry layer measured.

        Callable live (mid-``serve``) or at end of run:

        * ``metrics`` — registry counters/gauges/histograms (per-request
          TTFT and inter-token latency histograms, queue-depth and
          slot-occupancy gauges, stage latency histograms);
        * ``stages`` — prefill / decode.step / schedule.admit / sample
          wall-clock totals and span counts from the tracer;
        * ``requests`` — per-request lifecycle records
          (enqueue/admit/first-token/finish times, token counts, ITL
          aggregates) keyed by uid.  The table covers EVERY submitted
          request: ones still queued or decoding at snapshot time appear
          with ``status`` "queued"/"in_flight" and partial timings, not
          silently dropped;
        * ``sparsity`` — per-layer realized k/N and cross-step winner
          overlap from the probed decode steps, plus the staged
          execution-path attribution (topk/hadamard/dense × backend,
          est. FLOP shares, est. sparse-vs-dense decode time split).
        """
        stages = self.telemetry.tracer.totals()
        decode_total = stages.get("decode.step", {}).get("total_s")
        requests = {}
        if self._last_sched is not None:
            requests = {uid: rec.to_event()
                        for uid, rec in self._last_sched.records.items()}
        return {
            "enabled": self.telemetry.enabled,
            "metrics": self.telemetry.registry.snapshot(),
            "stages": stages,
            "requests": requests,
            "sparsity": {
                "layers": self._sparsity.summary(),
                "paths": self._dispatch.summary(decode_total),
                "probe_steps": self._sparsity.probes,
            },
        }

    # -- static-batch oracle -------------------------------------------------
    def generate_static(self, prompts: np.ndarray, gen_len: int):
        """The seed repo's static greedy path: prefill by stepping every
        prompt position through the decode kernel, then decode the batch in
        lockstep.  Exact but slow — kept as the correctness oracle for the
        continuous-batching engine (tests assert greedy parity)."""
        b, p_len = prompts.shape
        cache = self.new_cache(b)
        with use_rules(self.rules):
            logits = None
            for pos in range(p_len):
                batch = {"tokens": jnp.asarray(prompts[:, pos:pos + 1])}
                logits, cache = self._step(self.params, cache, batch,
                                           jnp.int32(pos))
            out = []
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for i in range(gen_len):
                out.append(np.asarray(cur))
                logits, cache = self._step(self.params, cache,
                                           {"tokens": cur},
                                           jnp.int32(p_len + i))
                cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)


#: Backwards-compatible alias — the seed exposed ``Server`` with a
#: ``generate`` method; examples and older scripts keep working.
class Server(Engine):
    def generate(self, prompts: np.ndarray, gen_len: int):
        return self.generate_static(prompts, gen_len)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--use-pallas", choices=("auto", "force", "off"),
                    default=None,
                    help="kernel executor override for the sparse paths "
                    "(default: the config's own setting)")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV cache layout: 'paged' decouples KV memory "
                    "from max_seq*slots (block allocator + chunked "
                    "prefill); 'contiguous' is the parity oracle")
    ap.add_argument("--page-size", type=int, default=16,
                    help="token rows per KV page (paged layout)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV pool size in pages (default: full backing, "
                    "slots*ceil(max_seq/page_size)+1)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill chunk rows, multiple of page-size "
                    "(default: 4 pages)")
    ap.add_argument("--kv-policy", choices=("reserve", "grow"),
                    default="grow",
                    help="paged admission policy: 'grow' admits on the "
                    "prompt footprint, extends chains lazily and preempts "
                    "(recompute-on-resume) when the pool runs dry; "
                    "'reserve' pins the worst case at admit (the "
                    "scheduling oracle)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable runtime telemetry (repro.obs) and print "
                    "a metrics snapshot at end of run")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="PATH",
                    help="stream telemetry events to PATH as JSON lines "
                    "(implies --telemetry)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))
    telemetry = None
    if args.telemetry or args.telemetry_jsonl:
        telemetry = Telemetry.on(jsonl_path=args.telemetry_jsonl)
    engine = Engine(cfg, mesh, max_seq=args.prompt_len + args.gen + 1,
                    n_slots=args.slots, use_pallas=args.use_pallas,
                    telemetry=telemetry, kv_layout=args.kv_layout,
                    page_size=args.page_size, n_pages=args.n_pages,
                    prefill_chunk=args.prefill_chunk,
                    kv_policy=args.kv_policy)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).tolist(),
                    max_new_tokens=args.gen,
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k, seed=i))
            for i in range(args.requests)]
    out, stats = engine.serve(reqs)
    print(f"served {len(out)} requests, {stats['decode_steps']} decode "
          f"steps, {stats['prefill_calls']} prefill calls, "
          f"{stats['tok_s']:.1f} tok/s; sample: {out[0][:16]}")
    if telemetry is not None:
        import json as _json
        print(_json.dumps(engine.metrics_snapshot(), indent=2,
                          sort_keys=True))
        telemetry.close()


if __name__ == "__main__":
    main()
