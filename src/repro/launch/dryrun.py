import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization). Everything else follows.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production meshes, with 512 placeholder host devices.

For each cell this produces (appended incrementally to a JSON results file):
  * compile success on the 16x16 single-pod mesh AND the 2x16x16 multi-pod
    mesh (the multi-pod pass proves the 'pod' axis shards),
  * ``memory_analysis()`` per-device byte accounting (proves it fits),
  * ``cost_analysis()`` FLOPs/bytes (per-device, post-partitioning),
  * per-collective byte counts parsed from the compiled HLO,
  * the same three quantities for the *accounting* compiles (one scan unit,
    the embed/head step, the optimizer step) — XLA counts while-loop bodies
    once, so the roofline multiplies the unit terms by n_units (see
    launch/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.launch import steps as St
from repro.launch.hlo import (collective_bytes, cost_analysis_dict,
                              count_hlo_ops)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.common import dtype_of
from repro.optim import init_state
from repro.sharding import make_rules, param_sharding, use_rules

RESULTS_PATH = "experiments/dryrun_results.json"

# Archs that cannot run the long_500k cell (pure full-attention; DESIGN.md
# §7 records the skip rationale).
LONG_CONTEXT_OK = {"xlstm_350m", "zamba2_1p2b"}


def train_overrides(arch_id: str) -> TrainConfig:
    """Per-arch numerics needed to fit the assigned mesh (DESIGN.md §6)."""
    if arch_id == "qwen3_moe_235b_a22b":
        return TrainConfig(moment_dtype="bfloat16")  # optimizer compression
    return TrainConfig()


def model_overrides(arch_id: str, cfg: ModelConfig,
                    shape: ShapeConfig) -> ModelConfig:
    if arch_id == "qwen3_moe_235b_a22b":
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if shape.kind != "train" and shape.seq_len >= 32768:
        # prefill/decode at 32k+: keep flash blocks modest
        cfg = dataclasses.replace(cfg, flash_block=1024)
    return cfg


def _mem_dict(ma) -> Dict[str, float]:
    if ma is None:
        return {}
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes_est": float(ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
    }


def _cost_dict(ca: Dict[str, float]) -> Dict[str, float]:
    if not ca:
        return {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def _analyze(compiled) -> Dict[str, Any]:
    txt = compiled.as_text()
    return {
        "memory": _mem_dict(compiled.memory_analysis()),
        "cost": _cost_dict(cost_analysis_dict(compiled)),
        "collectives": collective_bytes(txt),
        "hlo_ops": count_hlo_ops(txt),
    }


def apply_overrides(cfg: ModelConfig, overrides: str) -> ModelConfig:
    """--override "a=b,ffn_sparsity.n=8,..." -> dataclasses.replace chain.

    Nested SparsityConfig fields use dotted paths; values are parsed as
    python literals when possible."""
    import ast
    for item in overrides.split(","):
        if not item:
            continue
        key, _, val = item.partition("=")
        try:
            val = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            pass
        if "." in key:
            outer, inner = key.split(".", 1)
            sub = dataclasses.replace(getattr(cfg, outer), **{inner: val})
            cfg = dataclasses.replace(cfg, **{outer: sub})
        else:
            cfg = dataclasses.replace(cfg, **{key: val})
    return cfg


def compile_cell(arch_id: str, shape_name: str, multi_pod: bool,
                 accounting: bool = True,
                 cfg_override=None, tcfg_override=None,
                 overrides: str = "") -> Dict[str, Any]:
    """Lower+compile one cell; returns the result record."""
    cfg = cfg_override or get_config(arch_id)
    shape = SHAPES[shape_name]
    cfg = model_overrides(arch_id, cfg, shape) if cfg_override is None else cfg
    if overrides:
        cfg = apply_overrides(cfg, overrides)
    tcfg = tcfg_override or train_overrides(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    if kind == "decode" and shape.global_batch < 8:
        kind = "decode_long"
    rules = make_rules(mesh, kind)
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_units": cfg.n_units,
        "pattern": list(cfg.block_pattern),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "time": {},
    }
    t0 = time.time()

    with use_rules(rules):
        params_s, specs = St.abstract_params(cfg)
        p_shard = param_sharding(specs, params_s, rules)
        batch = St.input_specs(cfg, shape)
        b_shard = {k: rules.sharding_for(v, batch[k].shape)
                   for k, v in St.batch_logical_specs(batch).items()}
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_s)
                       if jnp.issubdtype(x.dtype, jnp.floating))
        rec["n_params"] = n_params

        if shape.kind == "train":
            train_step, acfg = St.make_train_step(cfg, tcfg)
            opt_s = jax.eval_shape(lambda p: init_state(p, acfg), params_s)
            o_specs = {"mu": specs, "nu": specs, "step": ()}
            if tcfg.zero1:
                zspecs = St.zero1_specs(specs, params_s, rules)
                o_specs = {"mu": zspecs, "nu": zspecs, "step": ()}
            o_shard = {
                "mu": param_sharding(o_specs["mu"], opt_s["mu"], rules),
                "nu": param_sharding(o_specs["nu"], opt_s["nu"], rules),
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            }
            jitted = jax.jit(train_step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch)
            rec["time"]["lower"] = time.time() - t0
            compiled = lowered.compile()
            rec["time"]["compile"] = time.time() - t0 - rec["time"]["lower"]
            rec["full"] = _analyze(compiled)
            if accounting:
                rec.update(_accounting_train(cfg, tcfg, shape, mesh, rules,
                                             params_s, specs))
        elif shape.kind == "prefill":
            step = St.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_s, batch)
            rec["time"]["lower"] = time.time() - t0
            compiled = lowered.compile()
            rec["time"]["compile"] = time.time() - t0 - rec["time"]["lower"]
            rec["full"] = _analyze(compiled)
            if accounting:
                rec.update(_accounting_fwd(cfg, shape, mesh, rules,
                                           params_s, specs))
        else:  # decode
            step = St.make_serve_step(cfg)
            cache_s, c_specs = St.abstract_cache(cfg, shape.global_batch,
                                                 shape.seq_len)
            c_shard = param_sharding(c_specs, cache_s, rules)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, b_shard, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, cache_s, batch, pos)
            rec["time"]["lower"] = time.time() - t0
            compiled = lowered.compile()
            rec["time"]["compile"] = time.time() - t0 - rec["time"]["lower"]
            rec["full"] = _analyze(compiled)
            if accounting:
                rec.update(_accounting_decode(cfg, shape, mesh, rules,
                                              params_s, specs, cache_s,
                                              c_specs))
    rec["time"]["total"] = time.time() - t0
    rec["ok"] = True
    return rec


# ---------------------------------------------------------------------------
# Accounting compiles (per-unit / head / optimizer)
# ---------------------------------------------------------------------------

def _unit_slice(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)


def _acc_seq(cfg, shape) -> int:
    """Accounting sequence length: SSM-family units cost linearly in T (the
    chunk scan), so compile them at <=4096 and let the roofline scale by
    T/T_acc — full-T unrolled SSD compiles (256 chunks) take tens of
    minutes on this host. Attention-family units keep full T (quadratic).
    zamba2's single shared_attn gets an analytic quadratic correction in
    roofline.py."""
    ssm = any(k in ("mamba2", "mlstm", "slstm") for k in cfg.block_pattern)
    if ssm and shape.seq_len > 4096:
        return 4096
    return shape.seq_len


def _x_specs(cfg, shape, rules, seq=None):
    ct = dtype_of(cfg.compute_dtype)
    seq = seq or shape.seq_len
    x = jax.ShapeDtypeStruct((shape.global_batch, seq, cfg.d_model), ct)
    pos = jax.ShapeDtypeStruct((shape.global_batch, seq), jnp.int32)
    x_sh = rules.sharding_for(("batch", "seq", None), x.shape)
    pos_sh = rules.sharding_for(("batch", "seq"), pos.shape)
    return x, pos, x_sh, pos_sh


def _accounting_train(cfg, tcfg, shape, mesh, rules, params_s, specs):
    out = {}
    # one scan unit, fwd+bwd
    acc_cfg = dataclasses.replace(cfg, remat=False, unroll_inner=True)
    unit_step = St.make_unit_train_step(acc_cfg)
    up_s = _unit_slice(params_s["units"])
    up_specs = jax.tree.map(lambda s: tuple(s[1:]), specs["units"],
                            is_leaf=St._spec_leaf)
    up_shard = param_sharding(up_specs, up_s, rules)
    shared_s = params_s.get("shared")
    sh_shard = param_sharding(specs["shared"], shared_s, rules) \
        if shared_s is not None else None
    seq_acc = _acc_seq(cfg, shape)
    x, pos, x_sh, pos_sh = _x_specs(cfg, shape, rules, seq=seq_acc)
    jitted = jax.jit(unit_step,
                     in_shardings=(up_shard, sh_shard, x_sh, pos_sh))
    compiled = jitted.lower(up_s, shared_s, x, pos).compile()
    out["unit"] = _analyze(compiled)
    out["unit"]["scale_T"] = shape.seq_len / seq_acc
    out["unit"]["acc_seq"] = seq_acc

    # embed + head + loss fwd+bwd (always full T)
    x, pos, x_sh, pos_sh = _x_specs(cfg, shape, rules)
    head_step = St.make_head_train_step(cfg)
    table = params_s["embed"]["table"]
    tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
    t_sh = rules.sharding_for(("vocab", "embed"), table.shape)
    tok_sh = rules.sharding_for(("batch", None), tok.shape)
    compiled = jax.jit(head_step,
                       in_shardings=(t_sh, tok_sh, tok_sh, x_sh)).lower(
        table, tok, tok, x).compile()
    out["head"] = _analyze(compiled)

    # optimizer step
    opt_step = St.make_opt_step(cfg, tcfg)
    from repro.optim import AdamWConfig
    acfg = AdamWConfig(moment_dtype=dtype_of(tcfg.moment_dtype))
    opt_s = jax.eval_shape(lambda p: init_state(p, acfg), params_s)
    o_specs_m = St.zero1_specs(specs, params_s, rules) if tcfg.zero1 else specs
    p_shard = param_sharding(specs, params_s, rules)
    o_shard = {"mu": param_sharding(o_specs_m, opt_s["mu"], rules),
               "nu": param_sharding(o_specs_m, opt_s["nu"], rules),
               "step": jax.sharding.NamedSharding(
                   mesh, jax.sharding.PartitionSpec())}
    compiled = jax.jit(opt_step,
                       in_shardings=(p_shard, p_shard, o_shard),
                       donate_argnums=(0, 2)).lower(
        params_s, params_s, opt_s).compile()
    out["opt"] = _analyze(compiled)
    return out


def _accounting_fwd(cfg, shape, mesh, rules, params_s, specs):
    out = {}
    acc_cfg = dataclasses.replace(cfg, remat=False, unroll_inner=True)
    unit_step = St.make_unit_fwd_step(acc_cfg)
    up_s = _unit_slice(params_s["units"])
    up_specs = jax.tree.map(lambda s: tuple(s[1:]), specs["units"],
                            is_leaf=St._spec_leaf)
    up_shard = param_sharding(up_specs, up_s, rules)
    shared_s = params_s.get("shared")
    sh_shard = param_sharding(specs["shared"], shared_s, rules) \
        if shared_s is not None else None
    seq_acc = _acc_seq(cfg, shape)
    x, pos, x_sh, pos_sh = _x_specs(cfg, shape, rules, seq=seq_acc)
    compiled = jax.jit(unit_step,
                       in_shardings=(up_shard, sh_shard, x_sh, pos_sh)).lower(
        up_s, shared_s, x, pos).compile()
    out["unit"] = _analyze(compiled)
    out["unit"]["scale_T"] = shape.seq_len / seq_acc
    out["unit"]["acc_seq"] = seq_acc

    ct = dtype_of(cfg.compute_dtype)
    x, pos, x_sh, pos_sh = _x_specs(cfg, shape, rules)
    table = params_s["embed"]["table"]
    t_sh = rules.sharding_for(("vocab", "embed"), table.shape)

    def head_fwd(table, x):
        return (x @ table.astype(ct).T)[:, -1]

    x_sh2 = rules.sharding_for(("batch", "seq", None), x.shape)
    compiled = jax.jit(head_fwd, in_shardings=(t_sh, x_sh2)).lower(
        table, x).compile()
    out["head"] = _analyze(compiled)
    return out


def _accounting_decode(cfg, shape, mesh, rules, params_s, specs, cache_s,
                       c_specs):
    """One-unit decode step + head projection."""
    out = {}
    unit_cache = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), cache_s)
    uc_specs = jax.tree.map(lambda s: tuple(s[1:]), c_specs,
                            is_leaf=St._spec_leaf)
    uc_shard = param_sharding(uc_specs, unit_cache, rules)
    up_s = _unit_slice(params_s["units"])
    up_specs = jax.tree.map(lambda s: tuple(s[1:]), specs["units"],
                            is_leaf=St._spec_leaf)
    up_shard = param_sharding(up_specs, up_s, rules)
    shared_s = params_s.get("shared")
    sh_shard = param_sharding(specs["shared"], shared_s, rules) \
        if shared_s is not None else None
    ct = dtype_of(cfg.compute_dtype)
    x = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.d_model), ct)
    x_sh = rules.sharding_for(("batch", None, None), x.shape)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    from repro.models.transformer import _block_decode

    def unit_decode(unit_params, shared, x, unit_cache, pos):
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            x, new_cache[f"b{i}"] = _block_decode(kind, p, x, cfg,
                                                  unit_cache[f"b{i}"], pos)
        return x, new_cache

    compiled = jax.jit(unit_decode,
                       in_shardings=(up_shard, sh_shard, x_sh, uc_shard, None),
                       donate_argnums=(3,)).lower(
        up_s, shared_s, x, unit_cache, pos).compile()
    out["unit"] = _analyze(compiled)

    table = params_s["embed"]["table"]
    t_sh = rules.sharding_for(("vocab", "embed"), table.shape)

    def head_fwd(table, x):
        return (x @ table.astype(ct).T)[:, 0]

    compiled = jax.jit(head_fwd, in_shardings=(t_sh, x_sh)).lower(
        table, x).compile()
    out["head"] = _analyze(compiled)
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def iter_cells(mesh_sel: str):
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
                continue
            for mp in ([False, True] if mesh_sel == "both"
                       else [mesh_sel == "pod2"]):
                yield arch, shape, mp


def load_results(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def save_results(path: str, results: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-accounting", action="store_true")
    ap.add_argument("--override", default="",
                    help="cfg overrides, e.g. kv_cache_dtype=int8,"
                         "ffn_sparsity.route_share=64")
    ap.add_argument("--tag", default="", help="suffix for the result key")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    results = load_results(args.out)
    if args.all:
        cells = list(iter_cells(args.mesh))
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s, mp) for a in archs for s in shapes
                 for mp in ([False, True] if args.mesh == "both"
                            else [args.mesh == "pod2"])
                 if not (s == "long_500k"
                         and a.replace("-", "_") not in LONG_CONTEXT_OK
                         and a not in LONG_CONTEXT_OK)]

    for arch, shape, mp in cells:
        arch_id = arch.replace("-", "_").replace(".", "p")
        key = f"{arch_id}|{shape}|{'pod2' if mp else 'pod1'}"
        if args.tag:
            key += f"|{args.tag}"
        if key in results and results[key].get("ok") and not args.force:
            print(f"[skip] {key}")
            continue
        print(f"[run ] {key}", flush=True)
        t0 = time.time()
        try:
            rec = compile_cell(arch_id, shape, mp,
                               accounting=not args.no_accounting,
                               overrides=args.override)
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            rec = {"arch": arch_id, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = time.time() - t0
        results[key] = rec
        save_results(args.out, results)
        status = "OK" if rec.get("ok") else "FAIL"
        print(f"[{status:4s}] {key} ({rec['wall_s']:.1f}s)", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"done: {n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
