"""HLO-text analysis: collective byte accounting + host-transfer census.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled module text and sum operand sizes of every communication op.
Shapes in HLO text look like ``bf16[16,256,4096]{2,1,0}``; the parsed byte
count is the *per-device* payload of one execution of the op (HLO is the
per-device SPMD program).

Ops inside while-loop bodies execute once per trip; the roofline handles
trip multiplication at a higher level (per-unit accounting compiles,
launch/roofline.py) — here :func:`collective_stats` reports, per
collective kind, how many ops/bytes sit inside while bodies vs. at top
level so that mis-accounting is visible (a decode step is one while trip
per layer scan: a collective inside the body runs n_units times).

:func:`host_transfer_ops` lists every op that moves data across the
host/device boundary (send/recv, infeed/outfeed, host-memory-space
copies, ``MoveToHost``-family custom calls) — on the decode path any of
these is a latency cliff, and :mod:`repro.analysis` turns them into
findings.
"""

from __future__ import annotations

import re
from typing import Dict, List, Set, Tuple


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    JAX has flip-flopped on the return shape (a dict on new versions, a
    one-element list of dicts on 0.4.x); every caller in this repo goes
    through here so benchmarks and tests are version-tolerant."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def compiled_flops(compiled) -> float:
    """Total compiled FLOPs of a ``jax.stages.Compiled`` (0.0 when the
    backend reports none)."""
    return float(cost_analysis_dict(compiled).get("flops", 0.0))

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes per collective kind (one execution each).

    ``*-done`` ops are skipped (their ``*-start`` twin already counted)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    res = {f"{k}_bytes": v for k, v in out.items() if v}
    res.update({f"{k}_count": float(c) for k, c in counts.items() if c})
    res["total_bytes"] = sum(v for k, v in out.items())
    return res


# ---------------------------------------------------------------------------
# Computation segmentation + while-body accounting
# ---------------------------------------------------------------------------

# `%body.7 (arg: (...)) -> (...) {`  or  `ENTRY %main.42 (...) -> ... {`
# Headers always carry a parameter list and a `-> result_type {` tail; op
# lines carry an `=` before their first `(` and never end with `{`.
_COMPUTATION_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALLED_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALLED_BRACED_RE = re.compile(r"calls=\{([^}]*)\}")


def parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Split HLO text into ``{computation_name: [body lines]}``.

    The ENTRY computation is additionally indexed under ``"ENTRY"``."""
    out: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _COMPUTATION_RE.match(line.strip())
        if m and not line.strip().startswith("//"):
            current = m.group(2)
            out[current] = []
            if m.group(1):
                out["ENTRY"] = out[current]
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            out[current].append(line)
    return out


def _called_computations(lines: List[str]) -> Set[str]:
    called: Set[str] = set()
    for line in lines:
        called.update(_CALLED_RE.findall(line))
        for group in _CALLED_BRACED_RE.findall(line):
            called.update(n.strip().lstrip("%")
                          for n in group.split(",") if n.strip())
    return called


def while_body_computations(hlo_text: str) -> Set[str]:
    """Names of all computations reachable from a ``while`` op's body or
    condition (transitively through fusions/calls)."""
    comps = parse_computations(hlo_text)
    roots: Set[str] = set()
    for lines in comps.values():
        for line in lines:
            if re.search(r"=\s*(\([^)]*\)|\S+)\s+while\(", line):
                roots.update(_CALLED_RE.findall(line))
    seen: Set[str] = set()
    frontier = list(roots)
    while frontier:
        name = frontier.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        frontier.extend(_called_computations(comps[name]))
    return seen


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-kind collective counts/bytes split by while-body membership.

    Returns ``{kind}_count`` / ``{kind}_bytes`` (all occurrences, matching
    :func:`collective_bytes`) plus ``{kind}_in_while_count`` /
    ``{kind}_in_while_bytes`` for the subset staged inside while-loop
    bodies — those run once per trip (n_units trips for the layer-scan),
    so a roofline that reads the flat sum undercounts them."""
    comps = parse_computations(hlo_text)
    in_while = while_body_computations(hlo_text)
    stats: Dict[str, float] = {}

    def bump(key: str, bytes_: int) -> None:
        stats[key + "_count"] = stats.get(key + "_count", 0.0) + 1.0
        stats[key + "_bytes"] = stats.get(key + "_bytes", 0.0) + bytes_

    for name, lines in comps.items():
        if name == "ENTRY":
            continue  # alias of the entry computation's real name
        body = name in in_while
        for line in lines:
            if "-done(" in line:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            shape_str, kind = m.group(1), m.group(2)
            nbytes = _shape_bytes(shape_str)
            bump(kind, nbytes)
            if body:
                bump(f"{kind}_in_while", nbytes)
    return stats


# ---------------------------------------------------------------------------
# Host-transfer census
# ---------------------------------------------------------------------------

#: Ops that inherently cross the host/device boundary.
_HOST_OPS = ("send", "send-done", "recv", "recv-done", "infeed", "outfeed")
_HOST_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_HOST_OPS) + r")\(")
#: Custom calls that place/move buffers on host memory.
_HOST_CUSTOM_RE = re.compile(
    r'custom-call\(.*custom_call_target="'
    r'(MoveToHost|MoveToDevice|annotate_device_placement|PinToHost)"',
    re.S)
#: Host memory space annotation in a shape layout, e.g. ``f32[4]{0:S(5)}``.
_HOST_SPACE_RE = re.compile(r"\{[^}]*S\(5\)[^}]*\}")


def host_transfer_ops(hlo_text: str) -> List[Tuple[str, str]]:
    """Every op that moves data between host and device.

    Returns ``(op_kind, stripped_hlo_line)`` pairs: explicit send/recv and
    infeed/outfeed, ``MoveToHost``-family custom calls, and copies whose
    shape layout carries the host memory space ``S(5)``."""
    out: List[Tuple[str, str]] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _HOST_OP_RE.search(s)
        if m:
            out.append((m.group(1), s))
            continue
        m = _HOST_CUSTOM_RE.search(s)
        if m:
            out.append((m.group(1), s))
            continue
        if ("copy" in s or "custom-call" in s) and _HOST_SPACE_RE.search(s):
            out.append(("host-space-copy", s))
    return out


def count_hlo_ops(hlo_text: str) -> Dict[str, int]:
    """Coarse op census for perf archaeology: fusions, convolutions/dots,
    while loops, (re)materialization hints."""
    return {
        "dot": len(re.findall(r"= .*? dot\(", hlo_text)),
        "fusion": len(re.findall(r"fusion\(", hlo_text)),
        "while": len(re.findall(r"= .*? while\(", hlo_text)),
        "gather": len(re.findall(r"= .*? gather\(", hlo_text)),
        "scatter": len(re.findall(r"= .*? scatter\(", hlo_text)),
        "transpose": len(re.findall(r"= .*? transpose\(", hlo_text)),
        "lines": hlo_text.count("\n"),
    }
