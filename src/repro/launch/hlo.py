"""HLO-text analysis: collective byte accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled module text and sum operand sizes of every communication op.
Shapes in HLO text look like ``bf16[16,256,4096]{2,1,0}``; the parsed byte
count is the *per-device* payload of one execution of the op (HLO is the
per-device SPMD program).

Ops inside while-loop bodies execute once per trip; the roofline handles
trip multiplication at a higher level (per-unit accounting compiles,
launch/roofline.py) — here we also report, per collective kind, how many
ops sit inside while bodies vs. at top level so that mis-accounting is
visible.
"""

from __future__ import annotations

import re
from typing import Dict


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    JAX has flip-flopped on the return shape (a dict on new versions, a
    one-element list of dicts on 0.4.x); every caller in this repo goes
    through here so benchmarks and tests are version-tolerant."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def compiled_flops(compiled) -> float:
    """Total compiled FLOPs of a ``jax.stages.Compiled`` (0.0 when the
    backend reports none)."""
    return float(cost_analysis_dict(compiled).get("flops", 0.0))

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes per collective kind (one execution each).

    ``*-done`` ops are skipped (their ``*-start`` twin already counted)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    res = {f"{k}_bytes": v for k, v in out.items() if v}
    res.update({f"{k}_count": float(c) for k, c in counts.items() if c})
    res["total_bytes"] = sum(v for k, v in out.items())
    return res


def count_hlo_ops(hlo_text: str) -> Dict[str, int]:
    """Coarse op census for perf archaeology: fusions, convolutions/dots,
    while loops, (re)materialization hints."""
    return {
        "dot": len(re.findall(r"= .*? dot\(", hlo_text)),
        "fusion": len(re.findall(r"fusion\(", hlo_text)),
        "while": len(re.findall(r"= .*? while\(", hlo_text)),
        "gather": len(re.findall(r"= .*? gather\(", hlo_text)),
        "scatter": len(re.findall(r"= .*? scatter\(", hlo_text)),
        "transpose": len(re.findall(r"= .*? transpose\(", hlo_text)),
        "lines": hlo_text.count("\n"),
    }
