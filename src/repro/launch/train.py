"""End-to-end training driver with production fault tolerance.

Features (each one exercised by tests/test_train_loop.py):
  * auto-resume from the latest valid checkpoint (atomic + checksummed),
  * periodic async checkpointing + pruning,
  * SIGTERM/SIGINT preemption handler -> final checkpoint -> clean exit,
  * StepMonitor straggler detection -> elastic checkpoint-and-reshard hook,
  * LossGuard NaN/spike detection -> rollback to last checkpoint,
  * deterministic stateless data (resume reproduces the exact batch
    sequence),
  * optional int8 error-feedback gradient compression across the pod axis
    (pure-DP pod layouts),
  * works on any mesh: (1,1) on this CPU container up to (2,16,16).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100 --batch 8 --seq 128 --mesh 1x1 [--resume]
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import SHAPES, TrainConfig, get_config
from repro.configs.base import ShapeConfig
from repro.data import Prefetcher, batch_for
from repro.launch import steps as St
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.optim import init_state
from repro.runtime import LossGuard, StepMonitor
from repro.sharding import make_rules, param_sharding, use_rules


class Trainer:
    """Owns params/opt-state/mesh and the fault-tolerant step loop."""

    def __init__(self, cfg, tcfg: TrainConfig, mesh, shape: ShapeConfig,
                 reduced: bool = False):
        self.cfg = cfg
        self.tcfg = tcfg
        self.shape = shape
        self.mesh = mesh
        self.rules = make_rules(mesh, "train")
        self.monitor = StepMonitor()
        self.guard = LossGuard()
        self.step = 0
        self._preempted = False
        self._build()

    # -- construction -----------------------------------------------------

    def _build(self):
        cfg, tcfg = self.cfg, self.tcfg
        with use_rules(self.rules):
            params, specs = T.init_model(jax.random.PRNGKey(tcfg.seed), cfg)
            self.specs = specs
            self.p_shard = param_sharding(specs, params, self.rules)
            params = jax.device_put(params, self.p_shard)
            train_step, acfg = St.make_train_step(cfg, tcfg)
            self.acfg = acfg
            opt = init_state(params, acfg)
            zspecs = (St.zero1_specs(specs, params, self.rules)
                      if tcfg.zero1 else specs)
            self.o_shard = {
                "mu": param_sharding(zspecs, opt["mu"], self.rules),
                "nu": param_sharding(zspecs, opt["nu"], self.rules),
                "step": jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec()),
            }
            opt = jax.device_put(opt, self.o_shard)
            self.params, self.opt = params, opt
            self.b_specs = None
            self._jit = jax.jit(train_step, donate_argnums=(0, 1))

    def batch_sharding(self, batch):
        return {k: self.rules.sharding_for(
            ("batch",) + (None,) * (np.asarray(v).ndim - 1),
            np.asarray(v).shape) for k, v in batch.items()}

    # -- checkpoint/restore ------------------------------------------------

    def state_tree(self):
        return {"params": self.params, "opt": self.opt}

    def save(self, async_: bool = True):
        tree = self.state_tree()
        extra = {"step": self.step, "arch": self.cfg.name}
        if async_:
            return ckpt.save_async(self.tcfg.ckpt_dir, self.step, tree, extra)
        return ckpt.save(self.tcfg.ckpt_dir, self.step, tree, extra)

    def try_resume(self) -> bool:
        like = self.state_tree()
        shardings = {"params": self.p_shard, "opt": self.o_shard}
        step, tree, extra = ckpt.restore_latest(self.tcfg.ckpt_dir, like,
                                                shardings)
        if step is None:
            return False
        self.params, self.opt = tree["params"], tree["opt"]
        self.step = extra.get("step", step)
        return True

    def rollback(self) -> bool:
        """Loss blew up / NaN: restore the last checkpoint and skip
        forward past the bad step (fresh data, same params)."""
        ok = self.try_resume()
        if ok:
            self.step += 1  # skip the batch that produced the blow-up
        return ok

    # -- the loop ----------------------------------------------------------

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def run(self, total_steps: int, batch_fn, log=print):
        tcfg = self.tcfg
        pre = Prefetcher(batch_fn, self.step, depth=2)
        try:
            while self.step < total_steps and not self._preempted:
                _, batch = pre.get(expected_step=self.step)
                with use_rules(self.rules):
                    sh = self.batch_sharding(batch)
                    batch = {k: jax.device_put(v, sh[k])
                             for k, v in batch.items()}
                    self.monitor.start()
                    self.params, self.opt, metrics = self._jit(
                        self.params, self.opt, batch)
                    loss = float(metrics["loss"])
                    ev = self.monitor.stop(self.step)
                if not self.guard.check(loss):
                    log(f"[guard] step {self.step}: loss {loss} unhealthy; "
                        f"rolling back")
                    if not self.rollback():
                        raise RuntimeError(
                            f"loss diverged at step {self.step} with no "
                            f"checkpoint to roll back to")
                    continue
                if self.monitor.should_reshard:
                    log(f"[monitor] sustained stragglers at step "
                        f"{self.step}; checkpointing for elastic reshard")
                    self.save(async_=False)
                if self.step % tcfg.log_every == 0:
                    log(f"step {self.step:6d} loss {loss:.4f} "
                        f"({ev.duration*1e3:.0f} ms)")
                self.step += 1
                if self.step % tcfg.checkpoint_every == 0:
                    self.save()
                    ckpt.prune(tcfg.ckpt_dir, keep=3)
            if self._preempted:
                log(f"[preempt] signal received; checkpointing at step "
                    f"{self.step}")
            if ckpt.latest_step(tcfg.ckpt_dir) != self.step:
                self.save(async_=False)
        finally:
            pre.close()
        return self.step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = make_mesh(dims, axes)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       ckpt_dir=args.ckpt_dir,
                       checkpoint_every=max(10, args.steps // 5))
    trainer = Trainer(cfg, tcfg, mesh, shape)
    trainer.install_preemption_handler()
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")

    def batch_fn(step):
        return batch_for(cfg, shape, step, seed=tcfg.seed)

    t0 = time.time()
    final = trainer.run(args.steps, batch_fn)
    print(f"finished at step {final} in {time.time()-t0:.1f}s; "
          f"monitor: {trainer.monitor.summary()}")


if __name__ == "__main__":
    main()
