"""Launch: production meshes, dry-run sweep, roofline, train/serve
drivers."""
