"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production meshes: 16x16 per pod (256 chips), and the
    2-pod 512-chip mesh with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small runs (e.g. (2, 2) on 4 CPU
    devices)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
