"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """Version-tolerant ``jax.make_mesh``: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer JAX; older versions
    (<= 0.4.37) treat every axis as Auto already, so dropping the kwarg is
    semantics-preserving."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production meshes: 16x16 per pod (256 chips), and the
    2-pod 512-chip mesh with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small runs (e.g. (2, 2) on 4 CPU
    devices)."""
    return _make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
