"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape) cell on the single-pod mesh, derive the three terms
(instructions' formulas, applied to *per-device* quantities — HLO is the
per-device SPMD program and ``cost_analysis()`` reports post-partitioning
numbers):

    compute    = HLO_FLOPs / peak_FLOPs_chip          [s]
    memory     = HLO_bytes / HBM_bw_chip              [s]
    collective = collective_bytes / ICI_link_bw       [s]

Accounting model (XLA counts while-loop bodies ONCE, verified empirically):
  train:  term = unit_term * n_units + head_term + opt_term
  decode: term = unit_term * n_units + head_term
  prefill: same as decode accounting (unit fwd only)

where ``unit`` is the separately-compiled scan body (launch/dryrun.py),
compiled with ``unroll_inner=True`` so the flash/SSD chunk scans are fully
unrolled and counted exactly.  The only remaining under-count is the
sLSTM time-step recurrence (xlstm only; its in-loop einsum is ~1 of the
arch's ~8 matmuls per pattern — documented, not corrected).

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (+ attention KV reads)
for decode — the "useful" fraction MODEL_FLOPS / HLO_FLOPS exposes remat
and dispatch waste.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (the conservative single-link figure from the
assignment; a 2D-torus all-reduce can use more links, so collective terms
are upper bounds).

CAVEAT (documented in EXPERIMENTS.md): "bytes accessed" comes from the
CPU-backend compile, whose fusion granularity is far finer than a TPU's —
every fusion boundary counts full operand traffic, so the **memory term is
an upper bound** (the same workload fused by XLA:TPU moves several times
fewer HBM bytes). Compute FLOPs and collective payload bytes are
fusion-independent and robust. All hillclimb deltas compare like-for-like
on the same basis.
"""

from __future__ import annotations

import json
import math
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg) -> int:
    """Activated parameters per token (MoE: only top-k experts count)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    n_mats = 3 if cfg.act == "silu" else 2
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer = {}
    total = embed
    for kind in cfg.block_pattern:
        if kind in ("attn", "shared_attn"):
            h, hkv, dh = cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
            if cfg.use_mla:
                r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
                attn = d * cfg.n_heads * (dh + dr) + d * (r + dr) \
                    + r * 2 * cfg.n_heads * dh + cfg.n_heads * dh * d
            else:
                attn = d * h * dh + 2 * d * hkv * dh + h * dh * d
            if cfg.is_moe and kind == "attn":
                expert = n_mats * d * ff
                active_e = (cfg.experts_per_token
                            + cfg.n_shared_experts) * expert \
                    + d * cfg.n_experts
                ffn = active_e
            else:
                ffn = n_mats * d * ff
                if cfg.ffn_sparsity.weight_sparse:
                    ffn //= cfg.ffn_sparsity.n
            per_layer[kind] = attn + ffn
        elif kind == "mamba2":
            di = cfg.ssm_expand * d
            nh = di // cfg.ssm_head_dim
            per_layer[kind] = d * (2 * di + 2 * cfg.ssm_state + nh) + di * d
        elif kind == "mlstm":
            per_layer[kind] = d * 3 * d + d * 2 * cfg.n_heads + d * d
        elif kind == "slstm":
            dh_ = d // cfg.n_heads
            per_layer[kind] = d * 4 * d + cfg.n_heads * dh_ * 4 * dh_ + d * d
        total_unit = 0
    for kind in cfg.block_pattern:
        total += per_layer[kind] * cfg.n_units
    return int(total)


def inner_scan_x(cfg, shape_kind: str, seq_len: int) -> float:
    """Inner scans are unrolled in the accounting compiles; no correction
    factor remains (kept for API stability)."""
    del cfg, shape_kind, seq_len
    return 1.0


def cell_roofline(rec: Dict, cfg=None) -> Optional[Dict]:
    """Compute the three terms for one dry-run record (pod1)."""
    if not rec.get("ok") or "unit" not in rec:
        return None
    n_units = rec["n_units"]
    kind = rec["kind"]
    seq = rec["seq_len"]
    parts = ["unit", "head"] + (["opt"] if kind == "train" else [])
    x_inner = inner_scan_x(cfg, kind, seq) if cfg is not None else 1.0

    flops = bytes_ = coll = 0.0
    for p in parts:
        mult = n_units if p == "unit" else 1.0
        if p == "unit":
            mult *= rec["unit"].get("scale_T", 1.0)  # SSM linear-T scaling
        c = rec[p]["cost"]
        flops += c.get("flops", 0.0) * mult
        bytes_ += c.get("bytes_accessed", 0.0) * mult
        coll += rec[p]["collectives"].get("total_bytes", 0.0) * mult
    # zamba2's shared_attn inside a linearly-scaled SSM unit: add the
    # quadratic attention FLOPs the linear scaling misses (analytic).
    scale_t = rec.get("unit", {}).get("scale_T", 1.0)
    if cfg is not None and scale_t > 1.0:
        n_attn = sum(1 for k in cfg.block_pattern
                     if k in ("attn", "shared_attn"))
        if n_attn:
            t_full, t_acc = seq, rec["unit"]["acc_seq"]
            b_loc = rec["global_batch"] / 16
            h_loc = max(cfg.padded_heads / 16, 1)
            mult = 3.0 if kind == "train" else 1.0
            per_t2 = 2 * 2 * b_loc * h_loc * cfg.head_dim * 0.5 * mult
            delta = per_t2 * (t_full ** 2 - t_acc ** 2 * scale_t)
            flops += delta * n_attn * n_units
            bytes_ = bytes_  # byte/collective deltas left uncorrected (1
            # attn per 19 blocks; documented in EXPERIMENTS.md)

    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_ / HBM_BW
    coll_t = coll / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    bottleneck = max(terms, key=terms.get)
    bound_s = max(terms.values())

    out = {
        "flops_per_chip": flops, "bytes_per_chip": bytes_,
        "collective_bytes_per_chip": coll, **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "bound_s": bound_s,
        "inner_scan_x": x_inner,
    }
    if cfg is not None:
        n_act = active_params(cfg)
        chips = 256
        if kind == "train":
            tokens = rec["global_batch"] * rec["seq_len"]
            model_flops = 6 * n_act * tokens / chips
        elif kind == "prefill":
            tokens = rec["global_batch"] * rec["seq_len"]
            model_flops = 2 * n_act * tokens / chips
        else:  # decode: one token per sequence + KV attention reads
            model_flops = 2 * n_act * rec["global_batch"] / chips
            if not cfg.supports_long_context or any(
                    k.startswith("attn") or k == "shared_attn"
                    for k in cfg.block_pattern):
                n_attn = sum(1 for k in cfg.block_pattern
                             if k in ("attn", "shared_attn")) * rec["n_units"]
                kv_flops = (2 * 2 * rec["global_batch"] * rec["seq_len"]
                            * cfg.n_kv_heads * cfg.head_dim * n_attn)
                model_flops += kv_flops / chips
        out["model_flops_per_chip"] = model_flops
        out["useful_fraction"] = model_flops / flops if flops else 0.0
        out["mfu_at_bound"] = (model_flops / PEAK_FLOPS) / bound_s \
            if bound_s else 0.0
    return out


SUGGESTIONS = {
    ("train", "compute"): "cut HLO FLOPs: larger CS pack factor N on FFNs, "
                          "fewer remat recomputes (selective policies), or "
                          "offload head matmul to lower-precision",
    ("train", "memory"): "cut bytes: bf16 master/moments, fuse the routed "
                         "gather (Pallas grouped kernel), larger flash "
                         "blocks to amortize HBM traffic",
    ("train", "collective"): "cut collective bytes: reduce-scatter instead "
                             "of all-reduce+slice (ZeRO), overlap grad sync "
                             "with backward, int8 gradient compression "
                             "across pods",
    ("prefill", "compute"): "attention dominates at 32k: larger flash "
                            "blocks (MXU utilization), CS-pack projections",
    ("prefill", "memory"): "keep qkv in bf16 end-to-end; avoid f32 "
                           "score materialization",
    ("prefill", "collective"): "shard sequence (SP) to shrink per-chip "
                               "activations before TP collectives",
    ("decode", "compute"): "decode is rarely compute-bound; if so, the "
                           "sparse-sparse topk path (B*K < D_in) cuts MACs",
    ("decode", "memory"): "weight + KV bytes dominate: CS packing gives "
                          "~N x on weights; quantize KV cache to int8; "
                          "MLA-style latent caches",
    ("decode", "collective"): "replicate small weights instead of TP "
                              "all-gathers; batch multiple tokens per step",
}


def analyze(results_path: str = "experiments/dryrun_results.json",
            out_path: str = "experiments/roofline.json") -> Dict:
    from repro.configs import get_config
    with open(results_path) as f:
        results = json.load(f)
    table = {}
    for key, rec in results.items():
        parts = key.split("|")
        if len(parts) != 3:
            continue  # tagged hillclimb variants live in their own file
        arch, shape, mesh = parts
        if mesh != "pod1" or not rec.get("ok"):
            continue
        try:
            cfg = get_config(arch)
        except KeyError:
            cfg = None
        rl = cell_roofline(rec, cfg)
        if rl is None:
            continue
        rl["suggestion"] = SUGGESTIONS.get(
            (rec["kind"], rl["bottleneck"]), "")
        rl["peak_bytes_per_device"] = rec["full"]["memory"].get(
            "peak_bytes_est")
        table[f"{arch}|{shape}"] = rl
    with open(out_path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    return table


def to_markdown(table: Dict) -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "bottleneck | model GFLOP/chip | useful frac | MFU@bound | "
        "mem/dev (GB) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(table):
        r = table[key]
        arch, shape = key.split("|")
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['bottleneck']}** | "
            f"{r.get('model_flops_per_chip', 0)/1e9:.1f} | "
            f"{r.get('useful_fraction', 0):.2f} | "
            f"{r.get('mfu_at_bound', 0)*100:.1f}% | "
            f"{(r.get('peak_bytes_per_device') or 0)/1e9:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    table = analyze()
    print(to_markdown(table))
