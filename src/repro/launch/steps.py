"""Step functions + abstract initialization + input specs for every
(architecture x input-shape) cell.

Everything here works on ShapeDtypeStructs (no allocation) so the 235B
configs can be lowered/compiled on a CPU host with 512 placeholder devices.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.models.common import cross_entropy, dtype_of
from repro.optim import AdamWConfig, apply_updates, init_state, warmup_cosine
from repro.sharding import Rules, make_rules, param_sharding, use_rules


from repro.sharding.context import is_spec as _spec_leaf  # noqa: E402


# ---------------------------------------------------------------------------
# Abstract init
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct pytree, logical specs) without allocating.

    ``init_model`` is traced under eval_shape (so even the 235B table is
    just shapes); the specs — plain python data — are captured on the
    side."""
    pd = dtype_of(cfg.param_dtype)
    captured = {}

    def init():
        p, s = T.init_model(jax.random.PRNGKey(0), cfg)
        captured["specs"] = s
        return jax.tree.map(
            lambda x: x.astype(pd)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, p)

    shapes = jax.eval_shape(init)
    return shapes, captured["specs"]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    captured = {}

    def init():
        c, s = T.init_cache(cfg, batch, max_seq)
        captured["specs"] = s
        return c

    shapes = jax.eval_shape(init)
    return shapes, captured["specs"]


# ---------------------------------------------------------------------------
# Input specs (the assignment's input_specs() contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    ct = dtype_of(cfg.compute_dtype)
    if shape.kind == "decode":
        if cfg.frontend == "embed":
            return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), ct)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.frontend == "embed":
        batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), ct),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    elif cfg.frontend == "vision_prefix":
        s_txt = s - cfg.n_prefix
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s_txt), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct((b, cfg.n_prefix,
                                                  cfg.d_model), ct),
            "labels": jax.ShapeDtypeStruct((b, s_txt), jnp.int32),
        }
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        batch.pop("labels", None)
    return batch


def batch_logical_specs(batch) -> Dict[str, Tuple]:
    out = {}
    for k, v in batch.items():
        out[k] = ("batch",) + (None,) * (v.ndim - 1)
    return out


# ---------------------------------------------------------------------------
# ZeRO-1 moment specs
# ---------------------------------------------------------------------------

def zero1_specs(param_specs, param_shapes, rules: Rules):
    """Extend each moment leaf's spec with the DP axes on the first
    shardable (currently-replicated, divisible) dimension — optimizer-state
    sharding (ZeRO-1)."""
    from repro.sharding.axes import dp_axes
    dp = dp_axes(rules.mesh)
    if not dp:
        return param_specs
    dp_size = 1
    for a in dp:
        dp_size *= rules.mesh.shape[a]

    def extend(spec, shape_leaf):
        shape = shape_leaf.shape
        if len(spec) != len(shape):
            return spec
        spec = list(spec)
        for i, (ax, dim) in enumerate(zip(spec, shape)):
            # eligible if the dim currently resolves to no mesh axes
            resolved = rules.resolve(ax, dim) if isinstance(ax, str) else ax
            if resolved in (None, ()) and dim % dp_size == 0 and dim > 0:
                spec[i] = dp
                break
        return tuple(spec)

    return jax.tree.map(extend, param_specs, param_shapes,
                        is_leaf=lambda s: _spec_leaf(s))


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    acfg = AdamWConfig(lr=tcfg.lr, b1=tcfg.b1, b2=tcfg.b2,
                       weight_decay=tcfg.weight_decay,
                       grad_clip=tcfg.grad_clip,
                       moment_dtype=dtype_of(tcfg.moment_dtype))

    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(
            lf, has_aux=True, allow_int=True)(params)
        lr_scale = warmup_cosine(opt_state["step"], tcfg.warmup_steps,
                                 tcfg.total_steps)
        params, opt_state, om = apply_updates(params, grads, opt_state, acfg,
                                              lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step, acfg


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch, pos):
        return T.serve_step(params, cache, batch, pos, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = T.forward(params, batch, cfg)
        return logits[:, -1]  # next-token logits

    return prefill_step


# ---------------------------------------------------------------------------
# Accounting steps (roofline FLOP/collective sources; scan bodies are
# counted once by XLA cost analysis, so we compile one unit explicitly)
# ---------------------------------------------------------------------------

def make_unit_train_step(cfg: ModelConfig):
    """fwd+bwd through ONE superblock (the scan body) — cost_analysis of
    this, x n_units, is the layer-stack term of the roofline."""
    unit_fn = T.unit_step_fn(cfg)

    def step(unit_params, shared, x, positions):
        def lf(up, x):
            y, aux = unit_fn(up, shared, x, positions)
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux
        g, gx = jax.grad(lf, argnums=(0, 1), allow_int=True)(unit_params, x)
        return g, gx

    return step


def make_unit_fwd_step(cfg: ModelConfig):
    unit_fn = T.unit_step_fn(cfg)

    def step(unit_params, shared, x, positions):
        y, _ = unit_fn(unit_params, shared, x, positions)
        return y

    return step


def make_head_train_step(cfg: ModelConfig):
    """Embed + LM head + loss, fwd+bwd (the vocab term of the roofline)."""
    ct = dtype_of(cfg.compute_dtype)

    def step(table, tokens, labels, x):
        def lf(table, x):
            emb = jnp.take(table.astype(ct), tokens, axis=0)
            logits = x @ table.astype(ct).T
            return cross_entropy(logits[:, :-1], labels[:, 1:]) \
                + 0.0 * jnp.sum(emb.astype(jnp.float32) ** 2)
        return jax.grad(lf, argnums=(0, 1))(table, x)

    return step


def make_opt_step(cfg: ModelConfig, tcfg: TrainConfig):
    """The optimizer update alone (elementwise + ZeRO resharding
    collectives)."""
    acfg = AdamWConfig(moment_dtype=dtype_of(tcfg.moment_dtype))

    def step(params, grads, opt_state):
        p, s, m = apply_updates(params, grads, opt_state, acfg, 1.0)
        return p, s

    return step
