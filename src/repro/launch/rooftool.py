"""Print roofline terms for specific dry-run result keys (hillclimb
helper):

    PYTHONPATH=src python -m repro.launch.rooftool KEY [KEY...] \\
        [--results experiments/dryrun_results.json]
"""

import argparse
import json
import os
import sys

from repro.configs import get_config
from repro.launch.roofline import cell_roofline

DEFAULT_RESULTS = "experiments/dryrun_results.json"


def show(path, keys):
    if not os.path.exists(path):
        raise SystemExit(
            f"rooftool: results file {path!r} not found — run the dry-run "
            f"sweep first (python -m repro.launch.dryrun) or point "
            f"--results at an existing sweep output")
    with open(path) as f:
        results = json.load(f)
    for key in keys:
        rec = results.get(key)
        if rec is None:
            matches = [k for k in results if k.startswith(key)]
            for m in matches:
                show_one(m, results[m])
            if not matches:
                print(f"{key}: not found")
            continue
        show_one(key, rec)


def show_one(key, rec):
    if not rec.get("ok"):
        print(f"{key}: FAILED {rec.get('error','')[:120]}")
        return
    arch = key.split("|")[0]
    try:
        cfg = get_config(arch)
    except KeyError:
        cfg = None
    rl = cell_roofline(rec, cfg)
    if rl is None:
        print(f"{key}: no accounting data")
        return
    print(f"{key}:")
    print(f"  compute={rl['compute_s']*1e3:9.2f}ms  "
          f"memory={rl['memory_s']*1e3:9.2f}ms  "
          f"collective={rl['collective_s']*1e3:9.2f}ms  "
          f"-> {rl['bottleneck']}-bound")
    print(f"  mem/dev={rec['full']['memory'].get('peak_bytes_est',0)/1e9:.2f}GB  "
          f"useful={rl.get('useful_fraction',0):.3f}  "
          f"MFU@bound={rl.get('mfu_at_bound',0)*100:.2f}%")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.rooftool",
        description="Print roofline terms for dry-run result keys "
                    "(prefix match).")
    p.add_argument("keys", nargs="+", metavar="KEY",
                   help="result key or key prefix (e.g. 'smollm_360m|')")
    p.add_argument("--results", default=DEFAULT_RESULTS, metavar="PATH",
                   help=f"dry-run results JSON (default: {DEFAULT_RESULTS})")
    args = p.parse_args(argv)
    show(args.results, args.keys)
    return 0


if __name__ == "__main__":
    sys.exit(main())
