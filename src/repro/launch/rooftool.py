"""Print roofline terms for specific dry-run result keys (hillclimb
helper): PYTHONPATH=src python -m repro.launch.rooftool KEY [KEY...]"""

import json
import sys

from repro.configs import get_config
from repro.launch.roofline import cell_roofline


def show(path, keys):
    with open(path) as f:
        results = json.load(f)
    for key in keys:
        rec = results.get(key)
        if rec is None:
            matches = [k for k in results if k.startswith(key)]
            for m in matches:
                show_one(m, results[m])
            if not matches:
                print(f"{key}: not found")
            continue
        show_one(key, rec)


def show_one(key, rec):
    if not rec.get("ok"):
        print(f"{key}: FAILED {rec.get('error','')[:120]}")
        return
    arch = key.split("|")[0]
    try:
        cfg = get_config(arch)
    except KeyError:
        cfg = None
    rl = cell_roofline(rec, cfg)
    if rl is None:
        print(f"{key}: no accounting data")
        return
    print(f"{key}:")
    print(f"  compute={rl['compute_s']*1e3:9.2f}ms  "
          f"memory={rl['memory_s']*1e3:9.2f}ms  "
          f"collective={rl['collective_s']*1e3:9.2f}ms  "
          f"-> {rl['bottleneck']}-bound")
    print(f"  mem/dev={rec['full']['memory'].get('peak_bytes_est',0)/1e9:.2f}GB  "
          f"useful={rl.get('useful_fraction',0):.3f}  "
          f"MFU@bound={rl.get('mfu_at_bound',0)*100:.2f}%")


if __name__ == "__main__":
    show("experiments/dryrun_results.json", sys.argv[1:])
