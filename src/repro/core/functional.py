"""Differentiable execution paths for complementary-sparse linear maps.

Three interchangeable paths compute ``y = x @ W + b`` where W is an
(unmaterialized) complementary-sparse weight held as ``(packed, route)``:

1. ``cs_matmul`` — the **faithful paper algorithm** (Multiply → Route → Sum,
   §3.1/3.2) with routing hoisted offline into the weight layout, so the
   runtime re-orders *activations* with a static gather and contracts.
   FLOPs = 2·B·D_in·D_out/N (the paper's N× MAC reduction, exactly).

2. ``cs_matmul_dense`` — decompress-to-dense then matmul. FLOPs are dense but
   at-rest storage and (inside the Pallas kernel, see kernels/packed_matmul.py)
   HBM traffic are 1/N. This is the MXU-regime path.

3. ``cs_topk_matmul`` — the **sparse-sparse** path (§3.2): only the K
   non-zero activations fetch weight columns.
   FLOPs = 2·B·K·D_out (activation savings × the N× weight-memory savings).

Route sharing (beyond-paper, see DESIGN.md §3): ``route`` may be shared by
chunks of R consecutive output groups (shape (G/R, P, N)).  R=1 is the
faithful unconstrained layout; larger R turns the faithful path's contraction
into MXU-shaped (B,P)x(P,R) matmuls and divides the routed-activation
working set by R, at the cost of connectivity diversity.  All paths accept
any R; the algebra is identical.

Everything here is pure jnp and differentiable; JAX's autodiff transposes the
static gathers into static scatters, so the backward pass keeps the same
sparse operation count (no dense D_in×D_out object is ever built in path 1
or 3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .instrument import counted_top_k


def _layout_from(packed: jax.Array, route: jax.Array):
    """Infer (G, P, N, R) from packed (G,P,N) and route (G/R,P,N)."""
    g, p, n = packed.shape
    gr = route.shape[0]
    if route.shape[1:] != (p, n) or g % gr:
        raise ValueError(f"incompatible packed {packed.shape} / route {route.shape}")
    return g, p, n, g // gr


def route_to_gather_idx(route: jax.Array, n: int) -> jax.Array:
    """Flat input indices idx[gr,p,s] = p*N + route[gr,p,s] (int32)."""
    p = route.shape[1]
    return (jnp.arange(p, dtype=jnp.int32)[None, :, None] * n
            + route.astype(jnp.int32))


def cs_matmul(x: jax.Array, packed: jax.Array, route: jax.Array) -> jax.Array:
    """Faithful Multiply→Route→Sum path.

    Args:
      x: (..., D_in)
      packed: (G, P, N) pre-routed packed weights.
      route: (G/R, P, N) int permutations.

    Returns: (..., D_out = G*N)
    """
    g, p, n, r = _layout_from(packed, route)
    batch = x.shape[:-1]
    idx = route_to_gather_idx(route, n)          # (Gr, P, N) int32
    # Route the activations (static gather — the offline'd crossbar).
    xg = x[..., idx]                              # (..., Gr, P, N)
    pk = packed.reshape(g // r, r, p, n)          # (Gr, R, P, N)
    # Multiply + Sum: contract partitions. For R>1 this is a true matmul.
    y = jnp.einsum("...ups,urps->...urs", xg, pk)  # (..., Gr, R, N)
    return y.reshape(*batch, g * n)


def decompress(packed: jax.Array, route: jax.Array) -> jax.Array:
    """Materialize the sparse dense-format W (D_in, D_out) on device.

    Oracle + input to the dense-matmul path. The transpose of this scatter is
    a gather, so autodiff projects dense gradients back onto the packed
    support for free (masked-gradient training, paper §4 "static binary
    mask").
    """
    g, p, n, r = _layout_from(packed, route)
    idx = route_to_gather_idx(route, n)           # (Gr, P, N)
    idx_full = jnp.broadcast_to(idx[:, None], (g // r, r, p, n)).reshape(g, p, n)
    w = jnp.zeros((p * n, g, n), packed.dtype)
    # w[idx_full[g,p,s], g, s] = packed[g,p,s]
    gg = jnp.arange(g, dtype=jnp.int32)[:, None, None]
    ss = jnp.arange(n, dtype=jnp.int32)[None, None, :]
    w = w.at[idx_full, gg, ss].set(packed)
    return w.reshape(p * n, g * n)


def cs_matmul_dense(x: jax.Array, packed: jax.Array, route: jax.Array) -> jax.Array:
    """Decompress-then-matmul (MXU path; XLA fallback of the Pallas kernel)."""
    w = decompress(packed, route)
    return x @ w


def topk_support_flat(x: jax.Array, k: int):
    """Select step: the K largest-|x| positions as ``(vals, idx)``.

    ``idx`` is (..., K) int32 flat positions along the last axis — the same
    support form :func:`repro.core.kwta.kwta_support` hands off, so layers
    that already ran the Select can skip this call entirely.  Any superset
    of the true support is exact (extra entries multiply by x == 0).
    """
    _, sel = counted_top_k(jnp.abs(x), k)         # (..., K) indices
    vals = jnp.take_along_axis(x, sel, axis=-1)   # (..., K)
    return vals, sel.astype(jnp.int32)


def cs_topk_from_support(vals: jax.Array, p_idx: jax.Array, s_off: jax.Array,
                         packed: jax.Array, route: jax.Array) -> jax.Array:
    """Sparse-sparse Multiply-Route-Sum consuming an explicit support.

    The handoff form of :func:`cs_topk_matmul`: the Select already happened
    (k-WTA upstream), so this contracts the given K non-zeros against the
    packed weights without touching the scattered dense activation.

    Args:
      vals: (..., K) non-zero activation values.
      p_idx: (..., K) int partition index of each non-zero (flat_idx // N).
      s_off: (..., K) int offset-within-partition (flat_idx % N).
      packed: (G, P, N); route: (G/R, P, N).
    Returns: (..., D_out = G*N).
    """
    g, p, n, r = _layout_from(packed, route)
    batch = vals.shape[:-1]
    # Fetch the packed weight rows of the selected partitions. jnp.take with
    # multi-dim indices inserts them in place of axis 1:
    # packed (G, P, N) -> (G, ..., K, N); move G after K.
    wrow = jnp.take(packed, p_idx, axis=1)        # (G, ..., K, N)
    wrow = jnp.moveaxis(wrow, 0, -2)              # (..., K, G, N)
    rrow = jnp.take(route, p_idx, axis=1)         # (Gr, ..., K, N)
    rrow = jnp.moveaxis(rrow, 0, -2)              # (..., K, Gr, N)
    # An activation at offset s_off only owns slot s where route == s_off.
    hit = (rrow == s_off[..., None, None].astype(rrow.dtype))  # (..., K, Gr, N)
    hit = jnp.repeat(hit, r, axis=-2) if r > 1 else hit        # (..., K, G, N)
    contrib = wrow * hit.astype(wrow.dtype)       # (..., K, G, N)
    y = jnp.einsum("...k,...kgs->...gs", vals.astype(wrow.dtype), contrib)
    return y.reshape(*batch, g * n)


def cs_topk_matmul(x: jax.Array, packed: jax.Array, route: jax.Array,
                   k: int) -> jax.Array:
    """Sparse-sparse path: contract only the K largest-|x| positions.

    Exact whenever x is k-sparse with at most ``k`` non-zeros (the k-WTA
    contract); otherwise it is the paper's semantics of dropping all but the
    top-K contributions.  Runs its own Select — callers holding the k-WTA
    support should use :func:`cs_topk_from_support` instead (one Select per
    layer, Fig. 8a).

    Args:
      x: (..., D_in), expected k-sparse (output of k-WTA).
      k: static number of non-zeros to process.
    """
    n = packed.shape[2]
    vals, sel = topk_support_flat(x, k)
    return cs_topk_from_support(vals, sel // n, sel % n, packed, route)


def flops_cs_matmul(batch: int, d_in: int, d_out: int, n: int) -> int:
    """Theoretical MAC*2 count of the faithful path (the paper's claim)."""
    return 2 * batch * d_in * d_out // n


def flops_cs_topk(batch: int, k: int, d_out: int) -> int:
    return 2 * batch * k * d_out


def flops_dense(batch: int, d_in: int, d_out: int) -> int:
    return 2 * batch * d_in * d_out
