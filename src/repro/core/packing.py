"""Pack / unpack between sparse dense-format weights and the complementary
packed representation.

Packed layout (pre-routed — see DESIGN.md §3): for layout (G, P, N),

    packed[g, p, s] = W[p*N + route[g, p, s], g*N + s]

i.e. slot ``s`` of group ``g`` holds that output's (single) non-zero weight in
partition ``p``.  Because the permutation is applied to the *weights offline*,
the runtime only re-orders activations (a static gather) — this is the
paper's §3.1 remark "it may prove preferential to reorder the incoming
activations", which on TPU removes the crossbar entirely.

The paper's "Kernel ID" augmented tensor (§3.3.1, Fig. 8b) corresponds to the
(packed, route) pair: route *is* the Kernel-ID table, except stored inverse
(weight-major) because routing has been hoisted offline.
"""

from __future__ import annotations

import numpy as np

from .masks import CSLayout, validate_complementary


def pack_dense(layout: CSLayout, w: np.ndarray, route: np.ndarray,
               validate: bool = True) -> np.ndarray:
    """Pack a (masked) dense-format weight into (G, P, N).

    ``w`` is (d_in, d_out); entries off the complementary support are ignored
    (they are zero for a correctly-trained CS network).
    """
    g, p, n = layout.groups, layout.partitions, layout.n
    if w.shape != (layout.d_in, layout.d_out):
        raise ValueError(f"w shape {w.shape} != {(layout.d_in, layout.d_out)}")
    if validate:
        validate_complementary(layout, route)
    wr = w.reshape(p, n, g, n)  # [p, i, g, s]
    pp = np.arange(p)[None, :, None]
    gg = np.arange(g)[:, None, None]
    ss = np.arange(n)[None, None, :]
    # packed[g, p, s] = wr[p, route[g,p,s], g, s]
    return wr[pp, route.astype(np.int64), gg, ss]


def unpack(layout: CSLayout, packed: np.ndarray, route: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_dense`: reconstruct the sparse (d_in, d_out) W."""
    g, p, n = layout.groups, layout.partitions, layout.n
    w = np.zeros((p, n, g, n), packed.dtype)
    pp = np.arange(p)[None, :, None]
    gg = np.arange(g)[:, None, None]
    ss = np.arange(n)[None, None, :]
    w[pp, route.astype(np.int64), gg, ss] = packed
    return w.reshape(layout.d_in, layout.d_out)


def pack_conv(layout: CSLayout, w: np.ndarray, route: np.ndarray) -> np.ndarray:
    """Pack a conv kernel (kh, kw, c_in, c_out) along the filter dimension."""
    kh, kw, c_in, c_out = w.shape
    if kh * kw * c_in != layout.d_in or c_out != layout.d_out:
        raise ValueError(f"conv kernel {w.shape} incompatible with layout "
                         f"({layout.d_in}, {layout.d_out})")
    return pack_dense(layout, w.reshape(layout.d_in, c_out), route)


def unpack_conv(layout: CSLayout, packed: np.ndarray, route: np.ndarray,
                kh: int, kw: int, c_in: int) -> np.ndarray:
    w = unpack(layout, packed, route)
    return w.reshape(kh, kw, c_in, layout.d_out)


def packed_bytes(layout: CSLayout, weight_dtype_bytes: int = 2) -> dict:
    """Storage accounting (the paper's N-fold compression claim).

    Returns dense vs packed byte counts, including route-table overhead, for
    both random-permutation (int8/route-element) and cyclic (int8/partition)
    encodings.
    """
    dense = layout.d_in * layout.d_out * weight_dtype_bytes
    packed_w = layout.nnz * weight_dtype_bytes
    route_random = layout.groups * layout.partitions * layout.n  # int8 each
    route_cyclic = layout.groups * layout.partitions  # one shift each
    return {
        "dense_bytes": dense,
        "packed_weight_bytes": packed_w,
        "route_bytes_random": route_random,
        "route_bytes_cyclic": route_cyclic,
        "compression_random": dense / (packed_w + route_random),
        "compression_cyclic": dense / (packed_w + route_cyclic),
    }
