"""Trace-time instrumentation for the sparse execution paths.

The paper's Fig. 8a pipeline runs ONE Select (top-k / k-WTA) per sparse
layer; re-deriving the support downstream (e.g. ``cs_topk_matmul`` calling
``lax.top_k`` on an already k-sparse input) silently doubles the Select
cost.  Every Select call site in this repo goes through
:func:`counted_top_k`, so tests can trace a layer (``jax.make_jaxpr``) and
assert exactly one top_k was staged out per sparse layer:

    with count_selects() as c:
        jax.make_jaxpr(fn)(x)
    assert c.top_k == 1

Counters tick at *trace* time — inside ``lax.scan`` bodies they count once
per traced superblock, and jit cache hits don't tick them (use
``jax.make_jaxpr`` or a fresh function to force a trace when asserting).

The authoritative check of the one-Select invariant is the static pass in
:mod:`repro.analysis`, which counts ``top_k``/``sort`` primitives in the
staged jaxpr itself and therefore sees *every* Select, including ones that
bypass :func:`counted_top_k`.  The counters here remain as a lightweight
trace-time probe.
"""

from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Iterator

from jax import lax


class SelectCounter:
    """Per-``with``-block Select counts (see :func:`count_selects`)."""

    def __init__(self) -> None:
        self.counts = {"top_k": 0}

    @property
    def top_k(self) -> int:
        return self.counts["top_k"]

    def reset(self) -> None:
        for k in self.counts:
            self.counts[k] = 0


class _State(threading.local):
    def __init__(self) -> None:
        self.stack: list[SelectCounter] = []
        #: legacy process-global counter backing the deprecated
        #: ``topk_call_count``/``reset_topk_count`` API.
        self.legacy = SelectCounter()


_STATE = _State()


@contextlib.contextmanager
def count_selects() -> Iterator[SelectCounter]:
    """Count Select (top_k) call sites staged while the block is active.

    Scoped and re-entrant: each ``with`` block gets its own
    :class:`SelectCounter`, nested blocks all tick, and counters on other
    threads are untouched — concurrent tests can't corrupt each other's
    counts the way the old module-global counter could.
    """
    c = SelectCounter()
    _STATE.stack.append(c)
    try:
        yield c
    finally:
        _STATE.stack.remove(c)


def counted_top_k(x, k: int):
    """``lax.top_k`` that ticks every active Select counter (trace-time).

    Staged under a ``select`` name scope so the jaxpr-level Select-count
    rule (:mod:`repro.analysis`) can attribute each ``top_k`` primitive to
    the enclosing layer scope.
    """
    import jax
    for c in _STATE.stack:
        c.counts["top_k"] += 1
    _STATE.legacy.counts["top_k"] += 1
    with jax.named_scope("select"):
        return lax.top_k(x, k)


def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"repro.core.instrument.{name} is deprecated: the process-global "
        "counter is not safe under concurrent tracing. Use "
        "`with count_selects() as c:` instead.",
        DeprecationWarning, stacklevel=3)


def topk_call_count() -> int:
    """Deprecated shim: global Select count since the last reset."""
    _warn_deprecated("topk_call_count")
    return _STATE.legacy.top_k


def reset_topk_count() -> None:
    """Deprecated shim: reset the global Select counter."""
    _warn_deprecated("reset_topk_count")
    _STATE.legacy.reset()
