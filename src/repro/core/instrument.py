"""Trace-time instrumentation for the sparse execution paths.

The paper's Fig. 8a pipeline runs ONE Select (top-k / k-WTA) per sparse
layer; re-deriving the support downstream (e.g. ``cs_topk_matmul`` calling
``lax.top_k`` on an already k-sparse input) silently doubles the Select
cost.  Every Select call site in this repo goes through
:func:`counted_top_k`, so tests can trace a layer (``jax.make_jaxpr``) and
assert exactly one top_k was staged out per sparse layer.

The counter ticks at *trace* time — inside ``lax.scan`` bodies it counts
once per traced superblock, and jit cache hits don't tick it (use
``jax.make_jaxpr`` or a fresh function to force a trace when asserting).
"""

from __future__ import annotations

from jax import lax

_COUNTS = {"top_k": 0}


def counted_top_k(x, k: int):
    """``lax.top_k`` that ticks the Select counter (trace-time)."""
    _COUNTS["top_k"] += 1
    return lax.top_k(x, k)


def topk_call_count() -> int:
    """Number of Select (top_k) call sites staged since the last reset."""
    return _COUNTS["top_k"]


def reset_topk_count() -> None:
    _COUNTS["top_k"] = 0
