"""Public sparsity configuration + execution-path dispatch.

``SparsityConfig`` is the single object model configs use to turn the
paper's technique on for a layer family.  ``choose_path`` encodes the
regime analysis of DESIGN.md §2.1:

* sparse-sparse (``topk``) wins when B·K < D_in (small-batch serving),
* the faithful VPU Hadamard path wins when N >= vpu_crossover (~32),
* otherwise the MXU decompress path (``dense``) — dense-rate compute from
  1/N the weight memory.

Orthogonal to *which algorithm* runs is *which backend executes it*:
``choose_executor`` maps the config's ``use_pallas`` flag to a concrete
:class:`Executor` — the real Pallas kernels on TPU, their ``interpret``
fallback when forced on CPU (kernel-path tests), or the pure-jnp formulas
from :mod:`repro.core.functional` otherwise.  ``packed_linear_apply``
consults it so the serving engine can flip one flag to decode through the
batched sparse-sparse kernel.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Dict, Iterator, Literal, Optional

Path = Literal["auto", "hadamard", "dense", "topk"]

#: Backend selection for the Pallas kernels (see :func:`choose_executor`):
#: ``auto``  — Pallas on TPU, jnp elsewhere (the safe default);
#: ``force`` — Pallas everywhere, via ``interpret=True`` off-TPU;
#: ``off``   — always the jnp formulas (training baseline / debugging).
PallasMode = Literal["auto", "force", "off"]

#: MXU:VPU per-cycle FLOP ratio on TPU v5e (128x128 MXU vs 8x128 VPU).
VPU_CROSSOVER_N = 32


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Per-layer-family complementary-sparsity settings.

    Attributes:
      n: weight pack factor (density 1/n). n=1 disables weight sparsity.
      k_frac: activation k-WTA keep-fraction (None disables k-WTA).
      route_share: groups sharing one route table (1 = faithful paper
        layout; 0 = all groups share one table — the MXU-shaped variant).
      perm_kind: 'random' (faithful) or 'cyclic' (compressed routes).
      path: execution path override ('auto' dispatches by regime).
      kwta_impl: 'topk' (exact) or 'hist' (paper's histogram datapath).
      kwta_partitions: local k-WTA partition count (0 = global).
      use_pallas: kernel backend ('auto' = Pallas on TPU only, 'force' =
        Pallas everywhere with interpret fallback off-TPU, 'off' = jnp).
    """

    n: int = 1
    k_frac: Optional[float] = None
    route_share: int = 1
    perm_kind: str = "random"
    path: Path = "auto"
    kwta_impl: str = "topk"
    kwta_partitions: int = 0
    use_pallas: PallasMode = "auto"

    @property
    def weight_sparse(self) -> bool:
        return self.n > 1

    @property
    def activation_sparse(self) -> bool:
        return self.k_frac is not None and self.k_frac < 1.0

    def k_for(self, dim: int) -> int:
        """Static K for a given feature dim (multiple of kwta_partitions)."""
        if not self.activation_sparse:
            return dim
        k = max(1, int(round(dim * self.k_frac)))
        parts = max(1, self.kwta_partitions)
        k = max(parts, (k // parts) * parts)
        return min(k, dim)


DENSE = SparsityConfig()


@dataclasses.dataclass(frozen=True)
class Executor:
    """Resolved kernel backend for one layer application.

    ``use_pallas=False`` means the pure-jnp formulas run (XLA fuses them);
    ``use_pallas=True`` dispatches the Pallas kernels, with
    ``interpret=True`` whenever the current backend is not a TPU so the
    same code path is testable on CPU.
    """

    use_pallas: bool
    interpret: bool


def choose_executor(cfg: SparsityConfig) -> Executor:
    """Map ``cfg.use_pallas`` to a concrete backend decision.

    Backend-aware: 'auto' only engages the Pallas kernels on a real TPU
    (their interpret mode is correct but not fast); 'force' engages them
    everywhere, falling back to interpret mode off-TPU — the mode the
    kernel-parity tests and the CPU serving benchmark use to exercise the
    exact serving code path.
    """
    if cfg.use_pallas == "off":
        return Executor(use_pallas=False, interpret=False)
    import jax
    on_tpu = jax.default_backend() == "tpu"
    if cfg.use_pallas == "force":
        return Executor(use_pallas=True, interpret=not on_tpu)
    return Executor(use_pallas=on_tpu, interpret=False)


# ---------------------------------------------------------------------------
# Dispatch observation (runtime telemetry, repro.obs)
# ---------------------------------------------------------------------------

class _DispatchObs(threading.local):
    def __init__(self) -> None:
        self.stack: list = []


_DISPATCH_OBS = _DispatchObs()


@contextlib.contextmanager
def observe_dispatch(cb: Callable[[Dict], None]) -> Iterator[None]:
    """Register a *trace-time* observer of CS-layer dispatch decisions.

    While active (on this thread), every ``packed_linear_apply`` staged
    reports one event dict — ``{"path", "pallas", "interpret", "batch",
    "d_in", "d_out", "n", "k"}`` — describing which execution path and
    backend the layer chose.  Observation happens at trace time only:
    nothing is staged into the computation, and with no observer the
    notify below is a single thread-local list check.
    """
    _DISPATCH_OBS.stack.append(cb)
    try:
        yield
    finally:
        _DISPATCH_OBS.stack.remove(cb)


def dispatch_observed() -> bool:
    """True when a dispatch observer is active on this thread (callers
    skip building the event dict otherwise)."""
    return bool(_DISPATCH_OBS.stack)


def notify_dispatch(event: Dict) -> None:
    """Deliver a dispatch event to the active observers (if any)."""
    for cb in _DISPATCH_OBS.stack:
        cb(event)


def choose_path(cfg: SparsityConfig, batch: int, d_in: int,
                x_is_sparse: bool) -> str:
    """Regime dispatch (DESIGN.md §2.1)."""
    if cfg.path != "auto":
        return cfg.path
    if not cfg.weight_sparse:
        return "dense"
    if x_is_sparse and cfg.activation_sparse:
        k = cfg.k_for(d_in)
        if batch * k < d_in:
            return "topk"
    if cfg.n >= VPU_CROSSOVER_N:
        return "hadamard"
    # Moderate N: on TPU the MXU decompress kernel wins on compute; the
    # faithful path still wins on HLO-visible FLOPs. We default to the
    # faithful algorithm (paper baseline); perf configs override to 'dense'.
    return "hadamard"
