"""k-Winner-Take-All activation functions (paper §2.2.2, §3.3.3).

k-WTA replaces ReLU: exactly the K largest pre-activations propagate, the
rest are zeroed (winners keep their values).  Gradients flow only through
winners (this falls out of the scatter/gather formulation automatically —
straight-through on the support, zero elsewhere, matching [Ahmad &
Scheinkman 2019]).

Three implementations:

* :func:`kwta` — exact top-k via ``lax.top_k`` + scatter. The reference
  semantics and the training default.
* :func:`kwta_hist` — the paper's **histogram-threshold global k-WTA**
  (Fig. 10): build a value histogram, walk it from the top bin to find the
  smallest threshold retaining >= K values, keep everything above it.  Exact
  for quantized inputs with distinct bins; for continuous inputs may retain
  slightly more than K on bin ties (the paper's hardware has the same
  behavior — threshold compare, not an exact sort).
* :func:`kwta_local` — the paper's **local/partitioned k-WTA** (used after
  conv layers; competition within partitions).  On TPU we align partitions
  with the tensor-parallel shard so winner selection never crosses chips
  (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .instrument import counted_top_k


def kwta(x: jax.Array, k: int, axis: int = -1) -> jax.Array:
    """Exact k-WTA: keep the K largest values along ``axis``, zero the rest."""
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    x_m = jnp.moveaxis(x, axis, -1)
    y, _ = kwta_support(x_m, k)
    return jnp.moveaxis(y, -1, axis)


def kwta_support(x: jax.Array, k: int):
    """Exact k-WTA over the last axis that ALSO returns the winner support.

    This is the sparse-activation handoff (paper Fig. 8a: one Select per
    layer): the consumer of the k-sparse output — typically the next
    CS-packed projection's sparse-sparse path — takes ``(vals, idx)``
    directly instead of re-running ``lax.top_k`` on the scattered result.

    Returns ``(y, (vals, idx))`` where ``y`` is the k-sparse activation
    (same as :func:`kwta`), ``vals`` is (..., K) winner values and ``idx``
    is (..., K) int32 flat positions along the last axis.  When ``k >= d``
    the input is already dense and the support is ``None``.
    """
    d = x.shape[-1]
    if k >= d:
        return x, None
    vals, idx = counted_top_k(x, k)
    y = jnp.put_along_axis(jnp.zeros_like(x), idx, vals, axis=-1,
                           inplace=False)
    return y, (vals, idx.astype(jnp.int32))


def kwta_mask(x: jax.Array, k: int, axis: int = -1) -> jax.Array:
    """Boolean winner mask of exact k-WTA (ties broken by top_k order)."""
    x_m = jnp.moveaxis(x, axis, -1)
    _, idx = counted_top_k(x_m, min(k, x_m.shape[-1]))
    m = jnp.zeros(x_m.shape, jnp.bool_)
    m = jnp.put_along_axis(m, idx, True, axis=-1, inplace=False)
    return jnp.moveaxis(m, -1, axis)


def kwta_hist(x: jax.Array, k: int, bins: int = 256) -> jax.Array:
    """Histogram-threshold global k-WTA over the last axis (paper Fig. 10).

    Mirrors the FPGA datapath: quantize values to ``bins`` levels, histogram,
    cumulative-sum from the largest bin down until the running count reaches
    K, threshold-compare the inputs against the resulting cutoff.

    Retains *at least* K values (>= semantics at the threshold bin, like the
    hardware); exact when bin occupancy at the threshold is 1.
    """
    d = x.shape[-1]
    if k >= d:
        return x
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.where(hi > lo, (bins - 1) / (hi - lo), jnp.zeros_like(hi))
    b = jnp.clip(((x - lo) * scale), 0, bins - 1).astype(jnp.int32)  # (..., D)
    hist = jax.nn.one_hot(b, bins, dtype=jnp.int32).sum(axis=-2)  # (..., bins)
    # count of elements with bin >= t  (reverse cumulative sum)
    ccount = jnp.cumsum(hist[..., ::-1], axis=-1)[..., ::-1]
    # threshold bin: the largest t whose tail-count is still >= k
    ok = (ccount >= k)  # non-increasing in t -> last True
    tbin = jnp.sum(ok.astype(jnp.int32), axis=-1) - 1          # (...,)
    tbin = jnp.clip(tbin, 0, bins - 1)
    keep = b >= tbin[..., None]
    return x * keep.astype(x.dtype)


def kwta_bisect(x: jax.Array, k: int, iters: int = 16) -> jax.Array:
    """Threshold k-WTA via bisection on the value axis (SPMD-native).

    The sort/scatter lowering of exact top-k forces GSPMD to *replicate* the
    batch across the mesh (measured: a 10.7 GB all-gather per FFN at
    train_4k scale — see EXPERIMENTS.md §Perf).  This variant binary-searches
    the threshold instead: ``iters`` rounds of (compare + count) — pure
    elementwise + reduction ops that partition along every batch dim.

    Equivalent to walking the paper's histogram CDF (Fig. 10) to the K-th
    count with radix-2 refinement; like the hardware it keeps *at least* K
    values (>= threshold semantics, ties inclusive).
    """
    d = x.shape[-1]
    if k >= d:
        return x
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32, axis=-1, keepdims=True)
    hi = jnp.max(x32, axis=-1, keepdims=True)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((x32 >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        keep_going_down = cnt >= k      # threshold can move up
        lo = jnp.where(keep_going_down, mid, lo)
        hi = jnp.where(keep_going_down, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # lo is the largest probed threshold with count >= k
    return x * (x32 >= lo).astype(x.dtype)


def kwta_local(x: jax.Array, k: int, partitions: int, axis: int = -1) -> jax.Array:
    """Partitioned k-WTA: split ``axis`` into ``partitions`` equal groups and
    select k/partitions winners within each (paper's local k-WTA after convs;
    our per-TP-shard winner selection)."""
    x_m = jnp.moveaxis(x, axis, -1)
    d = x_m.shape[-1]
    if d % partitions:
        raise ValueError(f"dim {d} not divisible by partitions {partitions}")
    if k % partitions:
        raise ValueError(f"k {k} not divisible by partitions {partitions}")
    xp = x_m.reshape(*x_m.shape[:-1], partitions, d // partitions)
    yp = kwta(xp, k // partitions, axis=-1)
    return jnp.moveaxis(yp.reshape(x_m.shape), -1, axis)


def kwta_channel(x: jax.Array, k: int) -> jax.Array:
    """Convolutional k-WTA along the channel (last) dimension per spatial
    location — the paper's conv usage ('competition happens along the channel
    dimension')."""
    return kwta(x, k, axis=-1)


def activation_sparsity(x: jax.Array) -> jax.Array:
    """Fraction of zero entries (diagnostic; paper reports 88-90%)."""
    return jnp.mean((x == 0).astype(jnp.float32))
