"""Complementary Sparsity — the paper's primary contribution as a composable
JAX module.

Public surface:

* :class:`~repro.core.masks.CSLayout`, mask/route generation, packing.
* :class:`~repro.core.api.SparsityConfig` — per-layer sparsity settings.
* Execution paths (``cs_matmul`` faithful / ``cs_matmul_dense`` MXU /
  ``cs_topk_matmul`` sparse-sparse) in :mod:`repro.core.functional`.
* k-WTA activations in :mod:`repro.core.kwta`.
* Layers (``packed_linear_*``, ``packed_conv2d_*``) in
  :mod:`repro.core.layers`.
"""

from .api import (DENSE, Executor, SparsityConfig, choose_executor,
                  choose_path)
from .functional import (cs_matmul, cs_matmul_dense, cs_topk_from_support,
                         cs_topk_matmul, decompress, flops_cs_matmul,
                         flops_cs_topk, flops_dense, topk_support_flat)
from .instrument import (SelectCounter, count_selects, reset_topk_count,
                         topk_call_count)
from .kwta import (activation_sparsity, kwta, kwta_bisect, kwta_hist,
                   kwta_local, kwta_mask, kwta_support)
from .masks import (CSLayout, conv_layout, make_mask, make_routes,
                    pad_to_multiple, routes_to_mask, validate_complementary)
from .packing import pack_conv, pack_dense, packed_bytes, unpack, unpack_conv

__all__ = [
    "DENSE", "Executor", "SparsityConfig", "choose_executor", "choose_path",
    "cs_matmul", "cs_matmul_dense", "cs_topk_from_support", "cs_topk_matmul",
    "decompress", "flops_cs_matmul", "flops_cs_topk", "flops_dense",
    "topk_support_flat", "SelectCounter", "count_selects",
    "reset_topk_count", "topk_call_count",
    "activation_sparsity", "kwta", "kwta_bisect", "kwta_hist", "kwta_local",
    "kwta_mask", "kwta_support",
    "CSLayout", "conv_layout", "make_mask", "make_routes", "pad_to_multiple",
    "routes_to_mask", "validate_complementary",
    "pack_conv", "pack_dense", "packed_bytes", "unpack", "unpack_conv",
]
