"""Parameterized layers: dense and complementary-sparse linear / conv2d.

Functional style (no framework): each layer is an ``init(key, ...) ->
(params, specs)`` + ``apply(params, x, ...)`` pair.  ``specs`` mirrors the
params pytree with logical-axis tuples consumed by repro.sharding.

Packed layers hold:
  packed  (G, P, N)  float   — pre-routed packed weights (trainable)
  route   (G/R, P, N) int8   — static complementary routing (not trainable)
  bias    (D_out,)    float  — optional

The packed weight's group dim G is the sharding analog of D_out: tensor
parallelism shards G exactly like a dense layer shards its output features,
and each shard carries its own slice of the route table.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import sparsity as obs_sparsity

from . import functional as F
from .api import (SparsityConfig, choose_executor, choose_path,
                  dispatch_observed, notify_dispatch)
from .kwta import kwta, kwta_bisect, kwta_hist, kwta_local, kwta_support
from .masks import CSLayout, make_routes
from .packing import pack_dense


# ---------------------------------------------------------------------------
# Dense linear (baseline)
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, bias: bool = True,
                out_axis: str = "mlp", in_axis: Optional[str] = None,
                dtype=jnp.float32):
    k_w, _ = jax.random.split(key)
    scale = 1.0 / np.sqrt(d_in)
    params = {"w": jax.random.uniform(k_w, (d_in, d_out), dtype, -scale, scale)}
    specs = {"w": (in_axis, out_axis)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        specs["b"] = (out_axis,)
    return params, specs


def linear_apply(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Complementary-sparse packed linear
# ---------------------------------------------------------------------------

def packed_linear_init(key, d_in: int, d_out: int, cfg: SparsityConfig,
                       bias: bool = True, seed: int = 0,
                       out_axis: str = "mlp", dtype=jnp.float32):
    """Initialize a packed CS linear layer.

    Initialization matches a dense layer restricted to the CS support: each
    output has fan-in D_in/N, so we scale by sqrt(N/D_in) (sparse-aware init,
    crucial for trainability at high sparsity).

    Dims that don't divide the pack factor are transparently padded (the
    paper's sets need not all be full, §3: 'the restriction applies only to
    each set being combined'); ``packed_linear_apply`` pads inputs / slices
    outputs back. The bias (when present) carries the logical d_out.
    """
    from .masks import pad_to_multiple
    d_in_p = pad_to_multiple(d_in, cfg.n)
    d_out_p = pad_to_multiple(d_out, cfg.n)
    layout = CSLayout(d_in_p, d_out_p, cfg.n, cfg.perm_kind)
    d_in, d_out_logical, d_out = d_in_p, d_out, d_out_p
    g, p, n = layout.groups, layout.partitions, layout.n
    r = g if cfg.route_share == 0 else min(cfg.route_share, g)
    while g % r:  # fall back to the nearest divisor
        r -= 1
    route_np = make_routes(CSLayout(d_in, n * (g // r), n, cfg.perm_kind), seed)
    scale = np.sqrt(cfg.n / d_in)
    packed = jax.random.uniform(key, (g, p, n), dtype, -scale, scale)
    params = {"packed": packed, "route": jnp.asarray(route_np)}
    specs = {"packed": (out_axis, None, None), "route": (out_axis, None, None)}
    if bias:
        params["b"] = jnp.zeros((d_out_logical,), dtype)
        specs["b"] = (out_axis,)
    return params, specs


def packed_linear_from_dense(w: np.ndarray, cfg: SparsityConfig, seed: int = 0,
                             bias: Optional[np.ndarray] = None):
    """Pack an existing (masked) dense weight (the paper's offline Combine)."""
    d_in, d_out = w.shape
    layout = CSLayout(d_in, d_out, cfg.n, cfg.perm_kind)
    g = layout.groups
    r = g if cfg.route_share == 0 else min(cfg.route_share, g)
    while g % r:
        r -= 1
    route = make_routes(CSLayout(d_in, layout.n * (g // r), layout.n,
                                 cfg.perm_kind), seed)
    route_full = np.broadcast_to(route[:, None], (g // r, r, *route.shape[1:]))
    route_full = route_full.reshape(g, *route.shape[1:])
    packed = pack_dense(layout, w, route_full)
    params = {"packed": jnp.asarray(packed), "route": jnp.asarray(route)}
    if bias is not None:
        params["b"] = jnp.asarray(bias)
    return params


def _topk_execute(vals, idx, packed, route, cfg: SparsityConfig):
    """Sparse-sparse Multiply-Route-Sum on an explicit support, dispatched
    to the batched Pallas kernel or the jnp formula per the executor."""
    n = packed.shape[2]
    p_idx, s_off = idx // n, idx % n
    ex = choose_executor(cfg)
    if ex.use_pallas:
        # deferred import: kernels.ops imports repro.core at module scope
        from repro.kernels.ops import topk_gather_support_op
        return topk_gather_support_op(vals, p_idx, s_off, packed, route,
                                      ex.interpret)
    return F.cs_topk_from_support(vals, p_idx, s_off, packed, route)


def packed_linear_apply(params, x, cfg: SparsityConfig,
                        x_is_sparse: bool = False, support=None):
    """Apply packed CS linear with regime dispatch (DESIGN.md §2.1).

    Handles padded layouts: inputs are zero-padded up to P*N, outputs are
    sliced back to the bias length (when a bias is present).

    ``support`` is the optional sparse-activation handoff from the
    upstream k-WTA (``apply_kwta(..., return_support=True)``): a
    ``(vals, idx)`` pair over the *unpadded* last axis.  On the topk path
    it replaces the re-derivation of the support (one Select per layer,
    paper Fig. 8a); other paths ignore it.  Which backend runs the topk
    contraction — batched Pallas kernel vs jnp — is the executor's call
    (``cfg.use_pallas``, see :func:`repro.core.api.choose_executor`)."""
    packed = params["packed"].astype(x.dtype)
    route = params["route"]
    d_in = packed.shape[1] * packed.shape[2]
    if x.shape[-1] < d_in:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, d_in - x.shape[-1])]
        x = jnp.pad(x, pad)
    batch = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    path = choose_path(cfg, batch, d_in, x_is_sparse)
    if dispatch_observed():
        # Trace-time dispatch telemetry (repro.obs): which path/backend
        # this layer application staged.  Pure Python — nothing lands in
        # the jaxpr.
        ex = choose_executor(cfg) if path == "topk" else None
        notify_dispatch({"path": path, "batch": batch, "d_in": d_in,
                         "d_out": packed.shape[0] * packed.shape[2],
                         "n": cfg.n, "k": cfg.k_for(d_in),
                         "pallas": bool(ex and ex.use_pallas),
                         "interpret": bool(ex and ex.interpret)})
    # The cs_<path> scope lets the static analyzer attribute every staged
    # primitive to the execution path that produced it (repro.analysis).
    with jax.named_scope(f"cs_{path}"):
        if path == "topk":
            if support is None:
                # No handoff: run this layer's own Select on the k-sparse x.
                vals, idx = F.topk_support_flat(x, cfg.k_for(d_in))
            else:
                # Handoff indices address the unpadded axis; zero-padding
                # only appends positions, so they stay valid in the padded
                # layout.
                vals, idx = support
            y = _topk_execute(vals, idx, packed, route, cfg)
        elif path == "dense":
            y = F.cs_matmul_dense(x, packed, route)
        else:
            y = F.cs_matmul(x, packed, route)
    if "b" in params:
        b = params["b"]
        y = y[..., :b.shape[0]] + b.astype(x.dtype)
    return y


def apply_kwta(x, cfg: SparsityConfig, return_support: bool = False):
    """Apply the configured k-WTA activation along the last axis.

    With ``return_support=True`` returns ``(y, support)`` where ``support``
    is the ``(vals, idx)`` winner set when the exact global top-k impl ran,
    else ``None`` (hist/bisect keep >= K values with no index form; local
    k-WTA selects per-partition).  Passing the support to the next
    ``packed_linear_apply`` makes the Select run once per layer."""
    if not cfg.activation_sparse:
        return (x, None) if return_support else x
    k = cfg.k_for(x.shape[-1])
    support = None
    if cfg.kwta_impl == "hist":
        y = kwta_hist(x, k)
    elif cfg.kwta_impl == "bisect":
        y = kwta_bisect(x, k)
    elif cfg.kwta_partitions > 1:
        y = kwta_local(x, k, cfg.kwta_partitions)
    else:
        y, support = kwta_support(x, k)
    # Realized-sparsity capture (repro.obs): when the serving engine's
    # probed decode step is tracing, report this layer's winner set (exact
    # top-k) or a staged nnz reduction (>=-K threshold impls).  With no
    # active capture — every other trace, including everything the static
    # linter checks — both calls return immediately and stage nothing.
    if support is not None:
        obs_sparsity.observe_support(support[0], support[1], x.shape[-1])
    elif obs_sparsity.capture_active():
        obs_sparsity.observe_activation(y)
    return (y, support) if return_support else y


# ---------------------------------------------------------------------------
# Conv2D (dense + packed) — NHWC, via im2col so conv reuses the CS algebra
# ---------------------------------------------------------------------------

def _same_pad(x, kh, kw):
    ph, pw = kh // 2, kw // 2
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "VALID") -> jax.Array:
    """Extract patches: (B, H, W, C) -> (B, OH, OW, kh*kw*C)."""
    if padding == "SAME":
        x = _same_pad(x, kh, kw)
    b, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = jnp.stack(
        [x[:, i:i + oh * stride:stride, j:j + ow * stride:stride, :]
         for i in range(kh) for j in range(kw)], axis=-2)
    return patches.reshape(b, oh, ow, kh * kw * c)


def conv2d_init(key, kh: int, kw: int, c_in: int, c_out: int,
                bias: bool = True, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    params = {"w": jax.random.uniform(key, (kh, kw, c_in, c_out), dtype,
                                      -scale, scale)}
    specs = {"w": (None, None, None, "mlp")}
    if bias:
        params["b"] = jnp.zeros((c_out,), dtype)
        specs["b"] = ("mlp",)
    return params, specs


def conv2d_apply(params, x, stride: int = 1, padding: str = "VALID"):
    w = params["w"].astype(x.dtype)
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def packed_conv2d_init(key, kh: int, kw: int, c_in: int, c_out: int,
                       cfg: SparsityConfig, bias: bool = True, seed: int = 0,
                       dtype=jnp.float32):
    """CS conv packed along the filter dimension (paper Fig. 7)."""
    params, specs = packed_linear_init(
        key, kh * kw * c_in, c_out, cfg, bias=bias, seed=seed, dtype=dtype)
    return params, specs


def packed_conv2d_apply(params, x, cfg: SparsityConfig, kh: int, kw: int,
                        stride: int = 1, padding: str = "VALID",
                        x_is_sparse: bool = False):
    cols = im2col(x, kh, kw, stride, padding)  # (B, OH, OW, kh*kw*C)
    return packed_linear_apply(params, cols, cfg, x_is_sparse=x_is_sparse)


def maxpool2d(x, size: int = 2, stride: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "VALID")
