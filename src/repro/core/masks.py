"""Complementary sparsity mask generation.

The paper's central structural constraint (§3, Fig. 7): N sparse weight
structures with mutually non-overlapping non-zero positions are overlaid into
one dense structure.  We realize the *partitioned* variant (paper Fig. 5d,
their FPGA implementation's choice): the input dimension is split into
partitions of size N and, within each output group of N outputs, every
partition is owned by the N outputs as an exact permutation.

Two permutation families are supported:

* ``random`` — faithful default: an arbitrary permutation per (group,
  partition), sampled from a seeded generator.  Matches the paper's "does not
  dictate the relative positions of the non-zero elements".
* ``cyclic`` — beyond-paper, hardware-codesigned variant: the permutation is a
  cyclic shift, so the route table stores one int8 per (group, partition)
  instead of N — route storage drops from G*P*N to G*P bytes and kernel-side
  decompression becomes a vector roll.

All functions are pure numpy (mask generation is an offline preprocessing
step, exactly as the paper's "Combine ... is done offline").
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

PermKind = Literal["random", "cyclic"]


@dataclasses.dataclass(frozen=True)
class CSLayout:
    """Static description of a complementary-sparse linear layer.

    Attributes:
      d_in: input features (must be divisible by ``n``).
      d_out: output features (must be divisible by ``n``).
      n: pack factor == partition size == weights-per-partition-per-output.
         Weight density is exactly ``1/n``.
      perm_kind: permutation family (see module docstring).
    """

    d_in: int
    d_out: int
    n: int
    perm_kind: PermKind = "random"

    def __post_init__(self) -> None:
        if self.n < 1:
            raise ValueError(f"pack factor n must be >= 1, got {self.n}")
        if self.d_in % self.n:
            raise ValueError(f"d_in={self.d_in} not divisible by n={self.n}")
        if self.d_out % self.n:
            raise ValueError(f"d_out={self.d_out} not divisible by n={self.n}")

    @property
    def groups(self) -> int:  # G
        return self.d_out // self.n

    @property
    def partitions(self) -> int:  # P
        return self.d_in // self.n

    @property
    def density(self) -> float:
        return 1.0 / self.n

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    @property
    def nnz(self) -> int:
        """Non-zeros of the *unpacked* sparse weight == packed element count."""
        return self.groups * self.partitions * self.n


def make_routes(layout: CSLayout, seed: int) -> np.ndarray:
    """Sample the complementary routing tensor.

    Returns ``route`` of shape (G, P, N) int8 where ``route[g, p, s]`` is the
    offset-within-partition of output-slot ``s``'s non-zero weight.  For every
    (g, p), ``route[g, p, :]`` is a permutation of ``range(N)`` — this *is*
    the complementarity guarantee: the N sparse columns of group g tile
    partition p with no collisions and no gaps.
    """
    g, p, n = layout.groups, layout.partitions, layout.n
    rng = np.random.default_rng(seed)
    if layout.perm_kind == "cyclic":
        shift = rng.integers(0, n, size=(g, p))
        route = (np.arange(n)[None, None, :] + shift[:, :, None]) % n
    else:
        # Batched random permutations via argsort of uniform keys.
        keys = rng.random((g, p, n))
        route = np.argsort(keys, axis=-1)
    if n > 127:
        return route.astype(np.int32)
    return route.astype(np.int8)


def routes_to_mask(layout: CSLayout, route: np.ndarray) -> np.ndarray:
    """Expand routes to the binary mask of the unpacked sparse weight.

    Returns ``mask`` (d_in, d_out) uint8 with mask[j, o] == 1 iff W[j, o] is a
    permitted non-zero.  Used to constrain training (the paper trains with a
    static binary mask, §4) and as the oracle for complementarity tests.
    """
    g, p, n = layout.groups, layout.partitions, layout.n
    mask = np.zeros((layout.d_in, layout.d_out), np.uint8)
    gg, pp, ss = np.meshgrid(
        np.arange(g), np.arange(p), np.arange(n), indexing="ij"
    )
    j = pp * n + route.astype(np.int64)  # input index
    o = gg * n + ss  # output index
    mask[j.ravel(), o.ravel()] = 1
    return mask


def validate_complementary(layout: CSLayout, route: np.ndarray) -> None:
    """Raise if ``route`` violates the complementarity invariants."""
    g, p, n = layout.groups, layout.partitions, layout.n
    if route.shape != (g, p, n):
        raise ValueError(f"route shape {route.shape} != {(g, p, n)}")
    sorted_r = np.sort(route.astype(np.int64), axis=-1)
    if not (sorted_r == np.arange(n)[None, None, :]).all():
        raise ValueError("route is not a permutation per (group, partition): "
                         "non-zero positions collide or leave gaps")


def make_mask(d_in: int, d_out: int, n: int, seed: int = 0,
              perm_kind: PermKind = "random") -> np.ndarray:
    """Convenience: complementary binary mask for a (d_in, d_out) weight."""
    layout = CSLayout(d_in, d_out, n, perm_kind)
    return routes_to_mask(layout, make_routes(layout, seed))


def conv_layout(kh: int, kw: int, c_in: int, c_out: int, n: int,
                perm_kind: PermKind = "random") -> CSLayout:
    """Layout for a conv kernel packed along the *filter* dimension (paper
    Fig. 7): the flattened (kh*kw*c_in) receptive field is the partitioned
    input dim; groups of N output channels are complementary."""
    return CSLayout(kh * kw * c_in, c_out, n, perm_kind)


def pad_to_multiple(d: int, n: int) -> int:
    """Smallest d' >= d with d' % n == 0 (for layers whose dims don't divide n)."""
    return ((d + n - 1) // n) * n
