"""Paged KV-cache tests: block-allocator invariants (unit + property),
PagedKV geometry/layout ops, page-gated admission policy, and token-exact
parity of the paged engine against the contiguous oracle — including
chunked prefill of prompts longer than one chunk and a page pool smaller
than full backing.

ISSUE 10 additions: the grow-on-demand path (lazy ``extend`` at page
boundaries, LRU preemption with recompute-on-resume, ref-counted
prefix sharing with copy-on-write) — allocator- and scheduler-level
here; the engine-level differential fuzz harness lives in
``tests/test_kvcache_fuzz.py``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine
from repro.models import transformer as T
from repro.runtime.kvcache import (NULL_PAGE, BlockAllocator, PagedKV,
                                   paged_view, paged_write_chunk,
                                   paged_write_rows, prefix_keys)
from repro.runtime.scheduler import Request, SamplingParams, Scheduler


# ---------------------------------------------------------------------------
# BlockAllocator: unit tests (pure Python, no jax)
# ---------------------------------------------------------------------------

def test_allocator_basics_and_accounting():
    a = BlockAllocator(n_pages=9, page_size=4)
    assert a.capacity == 8 and a.free_pages == 8 and a.used_pages == 0
    chain = a.allocate(0, 3)
    assert len(chain) == 3 and NULL_PAGE not in chain
    assert a.used_pages == 3 and a.occupancy == pytest.approx(3 / 8)
    assert a.chain(0) == chain
    assert a.live_uids() == [0]
    freed = a.release(0)
    assert sorted(freed) == sorted(chain)
    assert a.free_pages == 8
    a.check()


def test_allocator_pages_needed_rounds_up():
    a = BlockAllocator(n_pages=4, page_size=8)
    # ISSUE 10 regression: zero tokens need zero pages — the old
    # max(1, ...) made every empty-prompt admit burn a page for nothing
    assert a.pages_needed(0) == 0
    assert a.pages_needed(1) == 1
    assert a.pages_needed(8) == 1
    assert a.pages_needed(9) == 2
    assert a.pages_needed(17) == 3


def test_allocator_rejects_double_alloc_and_overflow():
    a = BlockAllocator(n_pages=4, page_size=2)  # capacity 3
    a.allocate(1, 2)
    with pytest.raises(ValueError):
        a.allocate(1, 1)             # uid already holds a chain
    assert not a.can_allocate(2)
    with pytest.raises(MemoryError):
        a.allocate(2, 2)             # only 1 page free
    with pytest.raises(ValueError):
        a.allocate(3, -1)            # negative page count
    assert a.allocate(3, 0) == []    # empty chain is legal (grow policy)
    with pytest.raises(KeyError):
        a.release(99)                # never allocated
    a.release(3)
    a.check()


def test_allocator_extend_grows_chain():
    a = BlockAllocator(n_pages=6, page_size=2)
    first = a.allocate(0, 2)
    more = a.allocate(1, 1)
    grown = a.extend(0, 2)
    assert a.chain(0) == first + grown
    assert not (set(grown) & set(first)) and not (set(grown) & set(more))
    with pytest.raises(MemoryError):
        a.extend(0, 1)               # pool exhausted
    with pytest.raises(KeyError):
        a.extend(7, 1)
    a.check()


def test_allocator_extend_exhaustion_keeps_chain_intact():
    """The grow-on-demand failure mode: a failed extend must raise
    MemoryError and leave the chain exactly as it was (the engine
    preempts a victim and retries)."""
    a = BlockAllocator(n_pages=5, page_size=2)       # 4 usable
    chain = a.allocate(0, 3)
    a.allocate(1, 1)
    with pytest.raises(MemoryError):
        a.extend(0, 2)               # only 0 free
    assert a.chain(0) == chain       # untouched by the failed extend
    a.check()
    a.release(1)
    assert a.extend(0, 1)            # now it fits
    a.check()


def test_allocator_free_list_is_lifo():
    """Recently freed pages are re-issued first — keeps the hot set
    small and makes use-after-free loud."""
    a = BlockAllocator(n_pages=8, page_size=2)
    a.allocate(0, 2)
    mid = a.allocate(1, 2)
    a.allocate(2, 2)
    freed = a.release(1)
    assert freed == mid
    # LIFO: the re-issue pops the most recently freed page last-in-first
    assert a.extend(0, 2) == mid[::-1]
    a.check()


def test_allocator_interleaved_extend_release_invariants():
    a = BlockAllocator(n_pages=10, page_size=2)
    a.allocate(0, 1)
    a.allocate(1, 2)
    for _ in range(3):
        a.extend(0, 1)
        a.check()
    a.release(1)
    a.check()
    a.extend(0, 2)
    a.check()
    assert a.chain_len(0) == 6
    a.release(0)
    a.check()
    assert a.free_pages == a.capacity


def test_allocator_refcounts_shared_and_fork():
    a = BlockAllocator(n_pages=8, page_size=2)
    parent = a.allocate(0, 3)
    child = a.allocate(1, 1, shared=parent[:2])      # adopt 2 pages
    assert child[:2] == parent[:2]
    assert a.page_ref(parent[0]) == 2
    assert a.page_shared(0, 0) and a.page_shared(1, 0)
    assert not a.page_shared(0, 2)
    a.check()
    # releasing the parent keeps the shared pages alive for the child
    freed = a.release(0)
    assert freed == [parent[2]]
    assert a.page_ref(parent[0]) == 1
    a.check()
    # fork clones the whole chain by reference
    forked = a.fork(1, 2)
    assert forked == a.chain(1)
    assert all(a.page_ref(p) == 2 for p in forked)
    with pytest.raises(ValueError):
        a.fork(1, 2)                 # child already holds a chain
    with pytest.raises(KeyError):
        a.fork(99, 3)
    a.release(1)
    a.release(2)
    a.check()
    assert a.free_pages == a.capacity


def test_allocator_cow_page():
    a = BlockAllocator(n_pages=6, page_size=2)       # 5 usable
    chain = a.allocate(0, 2)
    a.fork(0, 1)
    # shared page: cow swaps in a fresh one, old stays with the peer
    old_new = a.cow_page(0, 0)
    assert old_new is not None
    old, new = old_new
    assert old == chain[0] and new not in chain
    assert a.chain(0)[0] == new and a.chain(1)[0] == old
    assert a.page_ref(old) == 1 and a.page_ref(new) == 1
    a.check()
    # uniquely-held page: no copy needed
    assert a.cow_page(0, 0) is None
    assert a.cow_page(0, 1) is not None    # break the remaining share
    a.check()
    # exhausted pool: cow must raise, not corrupt
    a.allocate(2, 1)                 # takes the last free page
    a.fork(2, 3)
    with pytest.raises(MemoryError):
        a.cow_page(2, 0)             # shared, but 0 pages free
    a.check()


def test_prefix_keys_page_aligned_and_tail():
    toks = list(range(10))
    keys = prefix_keys(toks, page_size=4)
    assert len(keys) == 3            # 2 full pages + tail
    # full-page keys depend only on the token prefix through the page
    assert keys[:2] == prefix_keys(toks[:8] + [99, 98], 4)[:2]
    # the tail key is exact-length/exact-content
    assert keys[2] != prefix_keys(toks + [0], 4)[2]
    assert prefix_keys(toks[:8], 4) == keys[:2]      # no tail when aligned
    assert prefix_keys([], 4) == []


def test_prefix_keys_collision_resistant_digest():
    """Regression: the keys were builtin ``hash()`` values, and builtin
    hashes collide — ``hash(-1) == hash(-2)`` in CPython, so the old
    tuple-hash keys for the prompts ``[-1]`` and ``[-2]`` were EQUAL and
    a later request would silently adopt the wrong live pages (wrong
    tokens, invisible to ``check()``).  The sha256 digests must tell
    such prompts apart."""
    assert hash((-1,)) == hash((-2,))    # the builtin trap the digest avoids
    assert prefix_keys([-1], 4) != prefix_keys([-2], 4)
    keys = prefix_keys(list(range(10)), 4)
    assert all(isinstance(k, bytes) for k in keys)
    # full-page and tail keys live in disjoint namespaces: the same
    # token run keyed as a full page never matches it keyed as a tail
    assert prefix_keys([1, 2, 3, 4], 4) != prefix_keys([1, 2, 3, 4], 5)


def test_allocator_prefix_index_register_match_drop():
    a = BlockAllocator(n_pages=8, page_size=2)
    toks = [7, 3, 9, 1, 4]           # 2 full pages + 1 tail
    keys = prefix_keys(toks, 2)
    a.allocate(0, 3)
    assert a.register_chain_prefix(0, keys) == 3
    assert a.match_prefix(keys) == a.chain(0)
    # a prefix of the prompt matches only its full pages
    assert a.match_prefix(prefix_keys(toks[:4], 2)) == a.chain(0)[:2]
    # first registration wins; re-registering is a no-op
    assert a.register_chain_prefix(0, keys) == 0
    a.check()
    # adopting via allocate(shared=) bumps refcounts
    shared = a.match_prefix(keys)
    a.allocate(1, 0, shared=shared)
    assert all(a.page_ref(p) == 2 for p in shared)
    a.check()
    # entries die with the page: release both holders -> no matches
    a.release(0)
    assert a.match_prefix(keys) == shared            # child keeps it live
    a.release(1)
    assert a.match_prefix(keys) == []
    a.check()
    with pytest.raises(ValueError):
        a.register_prefix(keys[0], 99)               # dead page


def test_allocator_null_page_never_issued():
    a = BlockAllocator(n_pages=5, page_size=1)
    pages = []
    for uid in range(4):             # drain the whole pool
        pages += a.allocate(uid, 1)
    assert NULL_PAGE not in pages
    assert sorted(pages) == [1, 2, 3, 4]
    assert not a.can_allocate(1)
    a.check()


# ---------------------------------------------------------------------------
# BlockAllocator: alloc/free interleavings (property + seeded fallback)
# ---------------------------------------------------------------------------

def _run_interleaving(n_pages, page_size, ops):
    """Drive an alloc/release script against the invariant checker and a
    shadow model of who owns what; ops = [(uid, n_tokens or None), ...]
    where None means release."""
    a = BlockAllocator(n_pages, page_size)
    owned = {}
    for uid, tok in ops:
        if tok is None:
            if uid in owned:
                freed = a.release(uid)
                assert sorted(freed) == sorted(owned.pop(uid))
        elif uid not in owned:
            n = a.pages_needed(tok)
            if a.can_allocate(n):
                owned[uid] = a.allocate(uid, n)
        a.check()                    # no double-assignment, conservation
        live = [p for c in owned.values() for p in c]
        assert len(set(live)) == len(live)
        assert a.used_pages == len(live)
    for uid in list(owned):
        a.release(uid)
        a.check()
    assert a.free_pages == a.capacity  # chains reclaim fully


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 17), st.integers(1, 8),
       st.lists(st.tuples(st.integers(0, 5),
                          st.one_of(st.none(), st.integers(0, 40))),
                max_size=60))
def test_allocator_interleavings_property(n_pages, page_size, ops):
    _run_interleaving(n_pages, page_size, ops)


def test_allocator_interleavings_seeded():
    """Hypothesis-free twin of the property test, so the invariants are
    exercised even on environments without hypothesis installed."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        n_pages = int(rng.integers(2, 18))
        page_size = int(rng.integers(1, 9))
        ops = [(int(rng.integers(0, 6)),
                None if rng.random() < 0.4 else int(rng.integers(0, 41)))
               for _ in range(int(rng.integers(0, 60)))]
        _run_interleaving(n_pages, page_size, ops)


# ---------------------------------------------------------------------------
# PagedKV geometry + host-side page tables
# ---------------------------------------------------------------------------

def test_pagedkv_build_geometry():
    geo = PagedKV.build(max_seq=40, n_slots=4, page_size=16)
    assert geo.blocks_per_slot == 3          # ceil(40 / 16)
    assert geo.view_len == 48                # >= max_seq, masked overhang
    assert geo.n_pages == 4 * 3 + 1          # full backing + null page
    small = PagedKV.build(40, 4, page_size=16, n_pages=7)
    assert small.n_pages == 7
    with pytest.raises(ValueError):
        PagedKV.build(40, 4, page_size=16, n_pages=3)  # < one request
    with pytest.raises(ValueError):
        PagedKV.build(40, 4, page_size=0)


def test_pagedkv_tables_and_chunk_spans():
    geo = PagedKV.build(max_seq=32, n_slots=2, page_size=8)
    t = geo.empty_tables(2)
    assert t.shape == (2, 4) and (t == NULL_PAGE).all()
    geo.set_chain(t, 1, [5, 2])
    assert list(t[1]) == [5, 2, NULL_PAGE, NULL_PAGE]
    assert (t[0] == NULL_PAGE).all()
    geo.clear_chain(t, 1)
    assert (t == NULL_PAGE).all()
    with pytest.raises(ValueError):
        geo.set_chain(t, 0, [1, 2, 3, 4, 5])  # wider than the table
    assert geo.chunk_spans(20, 8) == [(0, 8), (8, 8), (16, 4)]
    assert geo.chunk_spans(8, 8) == [(0, 8)]
    with pytest.raises(ValueError):
        geo.chunk_spans(20, 12)               # not a page multiple


# ---------------------------------------------------------------------------
# layout ops: gather/scatter against a contiguous shadow
# ---------------------------------------------------------------------------

def test_paged_write_rows_and_view_roundtrip():
    P, n_pages = 4, 7
    pool = jnp.zeros((n_pages, P, 3), jnp.float32)
    # two slots, chains [1,2] and [5], slot 2 inactive (all null)
    pages = jnp.asarray([[1, 2], [5, NULL_PAGE], [NULL_PAGE, NULL_PAGE]],
                        jnp.int32)
    rows = jnp.asarray([[1., 1, 1], [2., 2, 2], [9., 9, 9]])
    pool = paged_write_rows(pool, rows, pages, jnp.asarray([5, 0, 3]))
    v = np.asarray(paged_view(pool, pages))
    assert v.shape == (3, 2 * P, 3)
    assert (v[0, 5] == 1.0).all()            # slot 0, pos 5 -> page 2 row 1
    assert (v[1, 0] == 2.0).all()            # slot 1, pos 0 -> page 5 row 0
    # the inactive slot's write landed in the null page, not a real one
    assert not (np.asarray(pool)[1:] == 9.0).any()
    assert (np.asarray(pool)[NULL_PAGE, 3] == 9.0).all()


def test_paged_write_chunk_pads_to_null_page():
    P = 4
    pool = jnp.zeros((5, P, 2), jnp.float32)
    chain = jnp.asarray([3, NULL_PAGE, NULL_PAGE], jnp.int32)  # 1-page chain
    rows = jnp.stack([jnp.full((2,), float(i + 1)) for i in range(8)])
    # 3 true rows at positions [2, 5): rows 3..7 are bucket padding and
    # must sink into the null page, NOT clobber a clamped real page
    pool = paged_write_chunk(pool, rows, chain, jnp.int32(2), jnp.int32(3))
    got = np.asarray(pool)
    assert (got[3, 2] == 1.0).all() and (got[3, 3] == 2.0).all()
    real = got[1:].copy()
    real[2, 2:] = 0.0                         # the two true rows on page 3
    # position 4 (3rd true row) wraps to block 1 -> null page, by design:
    # the chain is 1 page, so rows past it go to the sink too
    assert (real == 0.0).all()
    assert got[NULL_PAGE].any()               # padding mass went to the sink


# ---------------------------------------------------------------------------
# page-gated admission (scheduler policy, no jax)
# ---------------------------------------------------------------------------

def _req(uid, p_len, max_new=4, **kw):
    return Request(uid=uid, prompt=list(range(p_len)),
                   max_new_tokens=max_new, **kw)


def test_admission_gated_by_free_pages_not_slots():
    alloc = BlockAllocator(n_pages=5, page_size=4)     # 4 usable pages
    s = Scheduler(4, allocator=alloc)
    s.submit_many([_req(0, 8, max_new=4),   # 3 pages
                   _req(1, 1, max_new=3)])  # 1 page
    admitted = s.admit()
    assert [sl.request.uid for sl in admitted] == [0, 1]
    assert alloc.free_pages == 0
    s.submit(_req(2, 1, max_new=1))
    assert s.admit() == []                  # slots free, pages aren't
    for slot in s.slots:
        if slot.busy:
            for t in range(slot.request.max_new_tokens):
                s.record_token(slot, t)
    s.retire_done()
    assert alloc.free_pages == 4            # chains reclaimed on retire
    (slot,) = s.admit()
    assert slot.request.uid == 2
    alloc.check()


def test_admission_head_of_line_blocks_fifo():
    alloc = BlockAllocator(n_pages=4, page_size=2)     # 3 usable pages
    s = Scheduler(2, allocator=alloc)
    s.submit_many([_req(0, 8, max_new=2),   # 5 pages: never fits now
                   _req(1, 1, max_new=1)])  # 1 page: would fit
    assert s.admit() == []                  # strict FIFO: head blocks tail
    assert [r.uid for r in s.queue] == [0, 1]
    alloc.check()


def test_chunked_admit_sets_prefill_state():
    s = Scheduler(1, allocator=BlockAllocator(8, 2))
    s.submit(_req(0, 5))
    (slot,) = s.admit(chunked=True)
    assert slot.prefilling and slot.prefill_pos == 0
    assert s.decoding_slots() == []
    slot.prefill_pos = 5                    # engine finished the chunks
    assert not slot.prefilling
    assert s.decoding_slots() == [slot]


# ---------------------------------------------------------------------------
# grow-on-demand admission + preemption (scheduler policy, no jax)
# ---------------------------------------------------------------------------

def test_grow_admission_uses_prompt_footprint_only():
    alloc = BlockAllocator(n_pages=5, page_size=4)   # 4 usable pages
    s = Scheduler(4, allocator=alloc, kv_policy="grow")
    # worst-case footprints are 3+3 pages (would NOT both fit under
    # reserve); prompt footprints are 2+1 and fit together under grow
    s.submit_many([_req(0, 8, max_new=4), _req(1, 1, max_new=8)])
    admitted = s.admit(chunked=True)
    assert [sl.request.uid for sl in admitted] == [0, 1]
    assert alloc.chain_len(0) == 2 and alloc.chain_len(1) == 1
    assert alloc.free_pages == 1
    alloc.check()


def test_preemption_victim_is_youngest_admitted():
    alloc = BlockAllocator(n_pages=9, page_size=4)
    s = Scheduler(3, allocator=alloc, kv_policy="grow")
    s.submit_many([_req(0, 4), _req(1, 4), _req(2, 4)])
    s.admit(chunked=True)
    victim = s.preemption_victim()
    assert victim.request.uid == 2          # last admitted, least service
    assert s.preemption_victim(exclude=(victim.index,)).request.uid == 1


def test_preempt_requeues_at_head_with_generated_suffix():
    alloc = BlockAllocator(n_pages=9, page_size=4)
    s = Scheduler(2, allocator=alloc, kv_policy="grow")
    s.submit_many([_req(0, 4, max_new=6), _req(1, 3, max_new=2),
                   _req(2, 2, max_new=2)])
    s.admit(chunked=True)
    slot = s.slots[0]
    slot.prefill_pos = 4                    # prefill done
    for t in (11, 12, 13):
        s.record_token(slot, t)
    rng_state = slot.rng.bit_generator.state
    s.preempt(slot)
    # pages released, request back at the HEAD (before still-queued uid 2)
    assert not slot.busy
    assert 0 not in alloc.live_uids()
    assert [r.uid for r in s.queue] == [0, 2]
    resumed = s.queue[0]
    assert list(resumed.prompt) == list(_req(0, 4).prompt) + [11, 12, 13]
    assert resumed.max_new_tokens == 6
    assert s.records[0].status == "queued"
    assert s.records[0].preemptions == 1
    alloc.check()
    # re-admission restores generated tokens and the sampling rng, so
    # decode continues exactly where it left off
    (slot2,) = s.admit(chunked=True)
    assert slot2.request.uid == 0
    assert slot2.generated == [11, 12, 13]
    assert slot2.rng.bit_generator.state == rng_state
    assert slot2.pos == 7                   # len(prompt + generated)
    # done-accounting still counts against the ORIGINAL budget
    for t in (14, 15, 16):
        s.record_token(slot2, t)
    assert slot2.done
    s.retire_done()
    assert s.finished[0] == [11, 12, 13, 14, 15, 16]
    alloc.check()


def test_preempt_twice_rebuilds_from_original_prompt():
    """Regression: preempting an already-resumed request must rebuild
    ``original_prompt + ALL generated`` — the resumed request's .prompt
    already embeds the first round of generated tokens, and appending
    ``slot.generated`` to it again duplicated that round (corrupt KV
    context, wrong positions, possible max_seq overflow)."""
    alloc = BlockAllocator(n_pages=17, page_size=4)
    s = Scheduler(1, allocator=alloc, kv_policy="grow")
    orig = _req(0, 4, max_new=8)
    s.submit(orig)
    s.admit(chunked=True)
    slot = s.slots[0]
    slot.prefill_pos = 4
    for t in (11, 12):
        s.record_token(slot, t)
    s.preempt(slot)
    assert list(s.queue[0].prompt) == list(orig.prompt) + [11, 12]
    # resume, generate two more, preempt AGAIN: the rebuilt prompt must
    # hold each generated token exactly once
    (slot,) = s.admit(chunked=True)
    slot.prefill_pos = len(slot.request.prompt)
    for t in (13, 14):
        s.record_token(slot, t)
    s.preempt(slot)
    resumed = s.queue[0]
    assert list(resumed.prompt) == list(orig.prompt) + [11, 12, 13, 14]
    assert s.records[0].preemptions == 2
    alloc.check()
    # third leg runs to completion against the ORIGINAL budget
    (slot,) = s.admit(chunked=True)
    assert slot.generated == [11, 12, 13, 14]
    for t in (15, 16, 17, 18):
        s.record_token(slot, t)
    assert slot.done
    s.retire_done()
    assert s.finished[0] == [11, 12, 13, 14, 15, 16, 17, 18]
    alloc.check()


def test_grow_admission_adopts_registered_prefix_pages():
    alloc = BlockAllocator(n_pages=9, page_size=2)
    s = Scheduler(2, allocator=alloc, kv_policy="grow")
    parent = _req(0, 6, max_new=2)
    s.submit(parent)
    s.admit(chunked=True)
    # engine finished the parent's prefill and published its pages
    alloc.register_chain_prefix(0, prefix_keys(parent.prompt, 2))
    dup = _req(1, 6, max_new=2)             # same prompt (same _req range)
    s.submit(dup)
    (slot,) = s.admit(chunked=True)
    assert slot.request.uid == 1
    assert alloc.chain(1) == alloc.chain(0)  # all 3 pages adopted
    assert s.prefix_hit_pages == 3
    # prefill restarts at the last prompt token, never a full skip: the
    # final logits row must come from a real chunk forward (and its
    # shared-page write is what triggers copy-on-write in the engine)
    assert slot.prefill_pos == 5
    alloc.check()


# ---------------------------------------------------------------------------
# paged engine == contiguous engine, token for token
# ---------------------------------------------------------------------------

def _cfg(**overrides):
    base = dict(head_pad=0, compute_dtype="float32", param_dtype="float32")
    base.update(overrides)
    return get_config("smollm-360m").reduced(**base)


def _mixed_requests(cfg, plens, gens):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n).tolist(),
                    max_new_tokens=g, sampling=SamplingParams(seed=i))
            for i, (n, g) in enumerate(zip(plens, gens))]


def test_paged_engine_matches_contiguous_mixed_lengths():
    """The acceptance-criteria workload: 8 requests over 4 slots, mixed
    prompt/gen lengths (several prompts span multiple prefill chunks),
    greedy sampling — the paged engine must emit identical tokens, with
    a pool SMALLER than full backing so admission really gates on pages
    and reclamation really recycles them."""
    cfg = _cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    plens = [5, 19, 3, 26, 9, 14, 7, 22]
    gens = [6, 7, 8, 9, 10, 6, 7, 8]
    eng_c = Engine(cfg, mesh, max_seq=40, n_slots=4)
    out_c, _ = eng_c.serve(_mixed_requests(cfg, plens, gens))
    eng_p = Engine(cfg, mesh, max_seq=40, n_slots=4, kv_layout="paged",
                   page_size=8, n_pages=13, prefill_chunk=8,
                   params=eng_c.params)
    out_p, stats = eng_p.serve(_mixed_requests(cfg, plens, gens))
    assert out_p == out_c
    # prompts of 19/26/22 tokens took 3/4/3 chunks of 8 — prefill really
    # was chunked, not one monolithic call per prompt
    expected_chunks = sum(-(-n // 8) for n in plens)
    assert stats["prefill_chunks"] == expected_chunks
    assert stats["pages_capacity"] == 12


def test_paged_engine_int8_cache_variant():
    """The quantized-cache leaves (int8 rows + fp32 scales) go through
    the same generic gather/scatter; parity must hold there too."""
    cfg = _cfg(kv_cache_dtype="int8")
    mesh = make_mesh((1, 1), ("data", "model"))
    plens, gens = [11, 4, 17, 6], [5, 6, 5, 6]
    eng_c = Engine(cfg, mesh, max_seq=32, n_slots=2)
    out_c, _ = eng_c.serve(_mixed_requests(cfg, plens, gens))
    eng_p = Engine(cfg, mesh, max_seq=32, n_slots=2, kv_layout="paged",
                   page_size=8, prefill_chunk=8, params=eng_c.params)
    out_p, _ = eng_p.serve(_mixed_requests(cfg, plens, gens))
    assert out_p == out_c


def test_paged_engine_mla_cache_variant():
    """MLA latent caches (kv_lora + rope leaves instead of per-head K/V)
    page through the same generic gather/scatter; parity must hold with
    the compressed-cache leaf shapes too."""
    cfg = get_config("deepseek_v2_lite_16b").reduced(
        remat=False, n_experts=0, n_shared_experts=0, experts_per_token=0,
        d_ff=64, head_pad=0, compute_dtype="float32", param_dtype="float32")
    mesh = make_mesh((1, 1), ("data", "model"))
    plens, gens = [11, 4, 17, 6], [5, 6, 5, 6]
    eng_c = Engine(cfg, mesh, max_seq=32, n_slots=2)
    out_c, _ = eng_c.serve(_mixed_requests(cfg, plens, gens))
    eng_p = Engine(cfg, mesh, max_seq=32, n_slots=2, kv_layout="paged",
                   page_size=8, prefill_chunk=8, params=eng_c.params)
    out_p, _ = eng_p.serve(_mixed_requests(cfg, plens, gens))
    assert out_p == out_c


def test_paged_serve_rejects_oversized_request():
    cfg = _cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = Engine(cfg, mesh, max_seq=16, n_slots=2, kv_layout="paged",
                 page_size=8)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([_req(0, 10, max_new=10)])  # 20 rows > max_seq 16


def test_engine_rejects_bad_layout():
    cfg = _cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(cfg, mesh, max_seq=16, kv_layout="ragged")


def test_init_paged_cache_requires_attention_pattern():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, block_pattern=("mamba2",))
    with pytest.raises(NotImplementedError):
        T.init_paged_cache(cfg, n_pages=4, page_size=8)
