"""Fault-tolerance integration tests for the training driver:
checkpoint/resume determinism, rollback on loss blow-up, preemption."""

import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.configs.base import ShapeConfig
from repro.data import batch_for
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer


def _mk_trainer(tmp_path, total=20, seed=0, log_every=100):
    cfg = get_config("smollm-360m").reduced(
        d_model=64, d_ff=128, vocab_size=128, n_heads=4, n_kv_heads=2,
        head_pad=0, n_layers=2)
    shape = ShapeConfig("t", 32, 4, "train")
    tcfg = TrainConfig(lr=1e-3, total_steps=total, ckpt_dir=str(tmp_path),
                       checkpoint_every=5, log_every=log_every, seed=seed)
    mesh = make_mesh((1, 1), ("data", "model"))
    trainer = Trainer(cfg, tcfg, mesh, shape)
    batch_fn = lambda step: batch_for(cfg, shape, step, seed=seed)  # noqa
    return trainer, batch_fn, cfg, shape


def test_train_loss_decreases(tmp_path):
    trainer, batch_fn, *_ = _mk_trainer(tmp_path, total=30)
    losses = []
    trainer.run(30, batch_fn, log=lambda *a: losses.append(a))
    assert trainer.step == 30
    assert trainer.guard.ema is not None


def test_resume_is_deterministic(tmp_path):
    """Train 10 straight vs train 5 + crash + resume 5: identical
    parameters (stateless data + exact checkpoint restore)."""
    t1, batch_fn, *_ = _mk_trainer(tmp_path / "a", total=10)
    t1.run(10, batch_fn)
    ref = [np.asarray(x, np.float32) for x in jax.tree.leaves(t1.params)
           if hasattr(x, "dtype") and x.dtype.kind == "f"]

    t2, batch_fn2, *_ = _mk_trainer(tmp_path / "b", total=10)
    t2.tcfg_total = 5
    t2.run(5, batch_fn2)
    assert t2.step == 5
    # new trainer = simulated restart
    t3, batch_fn3, *_ = _mk_trainer(tmp_path / "b", total=10)
    assert t3.try_resume(), "no checkpoint found after phase 1"
    assert t3.step == 5
    t3.run(10, batch_fn3)
    got = [np.asarray(x, np.float32) for x in jax.tree.leaves(t3.params)
           if hasattr(x, "dtype") and x.dtype.kind == "f"]
    for a, b in zip(ref, got):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_rollback_on_nan(tmp_path):
    trainer, batch_fn, *_ = _mk_trainer(tmp_path, total=10)
    trainer.run(6, batch_fn)  # writes a checkpoint at step 5
    step_before = trainer.step
    # poison the guard as if a NaN appeared
    assert not trainer.guard.check(float("nan"))
    ok = trainer.rollback()
    assert ok
    # run(6) checkpoints its final step; rollback restores it and skips one
    assert trainer.step == 7
    # training continues fine after rollback
    trainer.run(10, batch_fn)
    assert trainer.step == 10


def test_preemption_checkpoint(tmp_path):
    trainer, batch_fn, *_ = _mk_trainer(tmp_path, total=100, log_every=1)
    trainer.install_preemption_handler()
    # deliver SIGTERM to ourselves after a few steps via the loop's log hook
    count = {"n": 0}

    def log(*a):
        count["n"] += 1
        if count["n"] == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    tcfg = trainer.tcfg
    final = trainer.run(100, batch_fn, log=log)
    assert final < 100, "preemption did not stop the loop"
    from repro import checkpoint as ckpt
    assert ckpt.latest_step(tcfg.ckpt_dir) == final


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint written under a (1,1) mesh restores onto (2,2) with the
    new shardings (elastic scaling), if enough devices exist."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    t1, batch_fn, cfg, shape = _mk_trainer(tmp_path, total=4)
    t1.run(4, batch_fn)

    tcfg = TrainConfig(lr=1e-3, total_steps=8, ckpt_dir=str(tmp_path),
                       checkpoint_every=5, log_every=100)
    mesh2 = make_mesh((2, 2), ("data", "model"))
    t2 = Trainer(cfg, tcfg, mesh2, shape)
    assert t2.try_resume()
    assert t2.step == 4
    t2.run(8, batch_fn)
    assert t2.step == 8
