"""k-WTA activation tests: exact top-k semantics, histogram-threshold
approximation bounds, locality, gradients (straight-through on winners)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (activation_sparsity, kwta, kwta_hist, kwta_local,
                        kwta_mask)


@given(st.integers(1, 64), st.integers(2, 6), st.integers(0, 999))
@settings(max_examples=40, deadline=None)
def test_kwta_exact_count_and_values(k, rows, seed):
    d = 128
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    y = kwta(x, k)
    nz = (y != 0).sum(axis=-1)
    assert (np.asarray(nz) == k).all()
    # winners keep their values; they are the k largest
    srt = jnp.sort(x, axis=-1)[:, ::-1]
    thresh = srt[:, k - 1:k]
    assert bool(jnp.all(jnp.where(y != 0, y >= thresh, True)))
    assert bool(jnp.all(jnp.where(y != 0, y == x, True)))


@given(st.integers(4, 40), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_kwta_hist_superset_of_topk(k, seed):
    """Histogram k-WTA keeps >= k values and always includes the true
    winners above the threshold bin (paper's >= semantics)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, 200)).astype(np.float32))
    yh = kwta_hist(x, k)
    nz = np.asarray((yh != 0).sum(axis=-1))
    assert (nz >= k).all()
    # histogram cannot keep more than k + (bin occupancy - 1) extras; with
    # 256 bins over 200 gaussian values the overshoot is small
    assert (nz <= k + 40).all()
    yk = kwta(x, k)
    # every exact winner strictly above the threshold survives in hist
    assert bool(jnp.all(jnp.where(yk != 0, (yh == yk) | (yh == 0), True)))


def test_kwta_hist_exact_for_quantized():
    """For 8-bit-style inputs with distinct bins, histogram k-WTA is exact
    (the paper's FPGA operates on 8-bit activations)."""
    rng = np.random.default_rng(0)
    vals = rng.choice(256, size=100, replace=False).astype(np.float32)
    x = jnp.asarray(vals)[None, :] / 255.0
    for k in [1, 5, 25, 99]:
        yh = kwta_hist(x, k)
        yk = kwta(x, k)
        np.testing.assert_array_equal(np.asarray(yh), np.asarray(yk))


def test_kwta_local_partition_counts():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 64))
    y = kwta_local(x, 8, partitions=4)
    yp = np.asarray(y).reshape(5, 4, 16)
    assert ((yp != 0).sum(axis=-1) == 2).all()  # 2 winners per partition


def test_kwta_gradient_straight_through():
    x = jnp.asarray([[3.0, 1.0, 2.0, 0.5]])
    g = jax.grad(lambda x: jnp.sum(kwta(x, 2) * jnp.arange(1.0, 5.0)))(x)
    np.testing.assert_allclose(np.asarray(g)[0], [1.0, 0.0, 3.0, 0.0])


def test_kwta_k_geq_d_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    np.testing.assert_array_equal(np.asarray(kwta(x, 8)), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(kwta_hist(x, 9)), np.asarray(x))


def test_activation_sparsity_metric():
    x = jnp.asarray([[1.0, 0.0, 0.0, 0.0]])
    assert float(activation_sparsity(x)) == 0.75


def test_kwta_mask_matches():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
    m = kwta_mask(x, 4)
    y = kwta(x, 4)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(y != 0))
