"""End-to-end reproduction test: the paper's GSC CNN (Table 1) trains on
synthetic keyword data in all three variants, and the sparse variants
deliver the paper's structural claims (FLOP reductions in the compiled
artifact, N-fold parameter compression)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import gsc_batch
from repro.launch.hlo import compiled_flops
from repro.models import gsc_cnn as G
from repro.optim import AdamWConfig, apply_updates, init_state


def _train(variant, steps=60, batch=32):
    cfg = G.GSCConfig(variant=variant)
    params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
    acfg = AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt = init_state(params, acfg)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: G.loss_fn(p, batch, cfg), has_aux=True,
            allow_int=True)(params)
        params, opt, _ = apply_updates(params, grads, opt, acfg)
        return params, opt, m

    first = last = None
    for s in range(steps):
        b = gsc_batch(seed=0, step=s, batch=batch)
        params, opt, m = step_fn(params, opt,
                                 {"x": jnp.asarray(b["x"]),
                                  "y": jnp.asarray(b["y"])})
        if s == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return cfg, params, first, last


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["dense", "sparse_dense",
                                     "sparse_sparse"])
def test_gsc_trains(variant):
    cfg, params, first, last = _train(variant)
    assert last < first * 0.7, (f"{variant}: loss {first:.3f} -> {last:.3f} "
                                f"did not decrease enough")


def test_flop_reduction_matches_paper_structure():
    """Compiled-FLOP reductions must be within the ballpark of the
    theoretical MAC accounting (and ordered dense > sparse-dense >
    sparse-sparse), mirroring the paper's Fig. 1 / Tables 2-3 structure."""
    flops = {}
    for v in ["dense", "sparse_dense", "sparse_sparse"]:
        cfg = G.GSCConfig(variant=v)
        params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
        x = jax.ShapeDtypeStruct((1, 32, 32, 1), jnp.float32)
        c = jax.jit(lambda p, x: G.forward(p, x, cfg)).lower(
            params, x).compile()
        flops[v] = compiled_flops(c)
    rd = flops["dense"] / flops["sparse_dense"]
    rs = flops["dense"] / flops["sparse_sparse"]
    assert rd > 4, f"sparse-dense reduction only {rd:.1f}x"
    # On TPU the *compiled-FLOP* metric shows the weight-sparsity cut; the
    # activation-sparsity multiplier lands on the memory side except in the
    # B*K < D_in regime (DESIGN.md §2.1) — the dispatcher correctly avoids
    # paths that would lose FLOPs, so ss ~= sd here and the multiplicative
    # 30x+ shows in theoretical_macs (and in the Pallas topk kernel).
    assert rs > 0.9 * rd, f"sparse-sparse regressed FLOPs: {rs:.1f}x"
    from repro.models.gsc_cnn import GSCConfig, theoretical_macs
    macs = theoretical_macs(GSCConfig())
    assert macs["speedup_ss"] > 30
    assert macs["speedup_ss"] > 2 * macs["speedup_sd"]


def test_parameter_compression():
    """The packed network must be ~N x smaller (paper: 2.5M -> 127k
    non-zeros at 95%; ours: n=16 on the big layers)."""
    def nbytes(variant):
        cfg = G.GSCConfig(variant=variant)
        params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
        return sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(params)
                   if jnp.issubdtype(x.dtype, jnp.floating))

    ratio = nbytes("dense") / nbytes("sparse_sparse")
    assert ratio > 8, f"parameter compression only {ratio:.1f}x"


def test_sparse_sparse_activation_sparsity():
    """The k-WTA layers must actually produce the configured sparsity
    (paper: 88-90%)."""
    cfg = G.GSCConfig(variant="sparse_sparse")
    params, _ = G.init_model(jax.random.PRNGKey(0), cfg)
    # instrument: run forward up to the linear k-WTA by reusing the model
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 1))
    logits = G.forward(params, x, cfg)
    assert np.isfinite(np.asarray(logits)).all()
    from repro.core.kwta import kwta_channel
    h = jax.random.normal(jax.random.PRNGKey(2), (8, 10, 10, 64))
    hk = kwta_channel(jax.nn.relu(h), cfg.conv_k)
    sparsity = float((np.asarray(hk) == 0).mean())
    assert sparsity > 0.85  # paper's 88-90%
