"""Differential fuzz harness for the grow-on-demand paged KV cache.

Random serving schedules — mixed prompt lengths, duplicated and extended
prompts (forcing prefix sharing + copy-on-write), small page pools
(forcing lazy growth and preemption) — run through BOTH engines:

* the paged engine under ``kv_policy="grow"`` (chains admitted on the
  prompt footprint, extended lazily, preempted under pressure, prefix
  pages shared copy-on-write), and
* the contiguous engine, the token-exact greedy oracle.

Every schedule must produce IDENTICAL tokens for every request, with
``BlockAllocator.check()`` asserting pool invariants after every
admit/extend/preempt/retire (``REPRO_KV_CHECK=1`` is set for the whole
module).  A failing schedule is printed as a replayable
``run_schedule(Schedule(...))`` literal, and hypothesis shrinks it to a
minimal reproducer.

Profiles (select with ``HYPOTHESIS_PROFILE``):

* ``dev`` (default): 20 examples — fast local signal.
* ``ci``: 200 examples, derandomized, no deadline — the pinned corpus
  the acceptance criteria count (CI's ``kv-fuzz`` job).
* ``nightly``: 1000 fresh-seed examples — the long haul behind
  ``workflow_dispatch``.

Without hypothesis installed the ``@given`` test skips and the seeded
``test_fuzz_seeded_schedules`` twin still runs the same harness, so the
differential oracle is exercised on bare environments too.
"""

import dataclasses
import os
from typing import Tuple

import numpy as np
import pytest

# paranoid mode for every engine in this module: allocator invariants
# are checked every serve-loop iteration, not only on drain
os.environ["REPRO_KV_CHECK"] = "1"

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine
from repro.runtime.scheduler import Request, SamplingParams

if HAVE_HYPOTHESIS:
    settings.register_profile("dev", max_examples=20, deadline=None)
    settings.register_profile("ci", max_examples=200, deadline=None,
                              derandomize=True, print_blob=True)
    settings.register_profile("nightly", max_examples=1000, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

# fixed geometry: ONE compile set per pool size, shared by every example
PAGE_SIZE = 4
N_SLOTS = 3
MAX_SEQ = 24          # blocks_per_slot = 6, so pools >= 7 pages work
PREFILL_CHUNK = 8
POOL_CHOICES = (8, 11, 16)   # usable capacity 7 / 10 / 15 (<= 16 pages)
MAX_PROMPT = 12
MAX_GEN = 6           # worst case ceil(18/4) = 5 pages <= every pool
VOCAB_DRAW = 256


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One replayable fuzz case: a pool size and the request batch
    (``(prompt_tokens, max_new_tokens)`` per request, submitted FIFO)."""
    n_pages: int
    requests: Tuple[Tuple[Tuple[int, ...], int], ...]


_ENGINES = {}


def _engines(n_pages):
    """(contiguous oracle, paged grow engine) for one pool size — cached
    so every example reuses the same compiled jits and weights."""
    if "oracle" not in _ENGINES:
        cfg = get_config("smollm-360m").reduced(
            d_model=128, d_ff=512, vocab_size=512, n_heads=4,
            n_kv_heads=2, head_pad=0, compute_dtype="float32",
            param_dtype="float32")
        mesh = make_mesh((1, 1), ("data", "model"))
        _ENGINES["oracle"] = Engine(cfg, mesh, max_seq=MAX_SEQ,
                                    n_slots=N_SLOTS)
    oracle = _ENGINES["oracle"]
    if n_pages not in _ENGINES:
        _ENGINES[n_pages] = Engine(
            oracle.cfg, oracle.mesh, max_seq=MAX_SEQ, n_slots=N_SLOTS,
            kv_layout="paged", page_size=PAGE_SIZE, n_pages=n_pages,
            prefill_chunk=PREFILL_CHUNK, params=oracle.params,
            kv_policy="grow")
    return oracle, _ENGINES[n_pages]


def _requests(sched: Schedule):
    return [Request(uid=i, prompt=list(p), max_new_tokens=g,
                    sampling=SamplingParams(seed=i))
            for i, (p, g) in enumerate(sched.requests)]


def run_schedule(sched: Schedule):
    """Run one schedule through oracle and grow engine; assert token
    parity and per-request budget.  Returns the paged stats so callers
    can accumulate coverage (preemptions / CoW / prefix hits)."""
    oracle, paged = _engines(sched.n_pages)
    out_c, _ = oracle.serve(_requests(sched))
    out_p, stats = paged.serve(_requests(sched))
    trace = f"run_schedule({sched!r})"
    assert out_p == out_c, (
        f"paged grow engine diverged from the contiguous oracle\n"
        f"  oracle: {out_c}\n  paged:  {out_p}\n  replay: {trace}")
    for i, (_, g) in enumerate(sched.requests):
        assert len(out_p[i]) <= g, f"budget overrun on uid {i}: {trace}"
        # greedy + no eos: every request must spend its full budget
        assert len(out_p[i]) == g, f"budget underrun on uid {i}: {trace}"
    return stats


def _np_schedule(rng: np.random.Generator) -> Schedule:
    """The strategy, mirrored for the seeded no-hypothesis twin: a few
    base prompts, each request either fresh, an exact duplicate (CoW
    pressure) or a base+suffix extension (prefix-sharing pressure)."""
    n_pages = int(rng.choice(POOL_CHOICES))
    bases = [tuple(int(t) for t in
                   rng.integers(0, VOCAB_DRAW, int(rng.integers(1, 13))))
             for _ in range(int(rng.integers(1, 4)))]
    reqs = []
    for _ in range(int(rng.integers(1, 9))):
        mode = rng.choice(("fresh", "dup", "extend"))
        if mode == "fresh":
            prompt = tuple(int(t) for t in rng.integers(
                0, VOCAB_DRAW, int(rng.integers(1, 13))))
        elif mode == "dup":
            prompt = bases[int(rng.integers(0, len(bases)))]
        else:
            base = bases[int(rng.integers(0, len(bases)))]
            ext = tuple(int(t) for t in rng.integers(
                0, VOCAB_DRAW, int(rng.integers(1, 5))))
            prompt = (base + ext)[:MAX_PROMPT]
        reqs.append((prompt, int(rng.integers(1, MAX_GEN + 1))))
    return Schedule(n_pages=n_pages, requests=tuple(reqs))


if HAVE_HYPOTHESIS:
    @st.composite
    def schedules(draw):
        n_pages = draw(st.sampled_from(POOL_CHOICES))
        tokens = st.integers(0, VOCAB_DRAW - 1)
        prompts = st.lists(tokens, min_size=1,
                           max_size=MAX_PROMPT).map(tuple)
        bases = draw(st.lists(prompts, min_size=1, max_size=3))
        reqs = []
        for _ in range(draw(st.integers(1, 8))):
            mode = draw(st.sampled_from(("fresh", "dup", "extend")))
            if mode == "fresh":
                prompt = draw(prompts)
            elif mode == "dup":
                prompt = draw(st.sampled_from(bases))
            else:
                base = draw(st.sampled_from(bases))
                ext = draw(st.lists(tokens, min_size=1,
                                    max_size=4).map(tuple))
                prompt = (base + ext)[:MAX_PROMPT]
            reqs.append((prompt, draw(st.integers(1, MAX_GEN))))
        return Schedule(n_pages=n_pages, requests=tuple(reqs))
else:  # pragma: no cover - strategy stub; the @given test is skipped
    def schedules():
        return st


@given(schedules())
def test_fuzz_grow_engine_matches_oracle(sched):
    run_schedule(sched)


def test_fuzz_seeded_schedules():
    """Hypothesis-free twin: 25 seeded random schedules through the same
    differential harness, so bare environments still fuzz the grow
    path.  The corpus must cover the interesting transitions at least
    once — growth, preemption, prefix adoption and a CoW break."""
    rng = np.random.default_rng(0)
    totals = {"preemptions": 0, "cow_copies": 0, "prefix_hit_pages": 0,
              "grown_pages": 0}
    for _ in range(25):
        stats = run_schedule(_np_schedule(rng))
        for k in totals:
            totals[k] += stats[k]
    assert totals["grown_pages"] > 0, totals
    assert totals["preemptions"] > 0, totals
    assert totals["prefix_hit_pages"] > 0, totals
    assert totals["cow_copies"] > 0, totals


def test_fuzz_forced_preemption_parity():
    """Deterministic pin of the corpus guarantee: a pool of 7 usable
    pages under six 15..22-row requests MUST preempt (recompute-on-
    resume) and still match the oracle token for token."""
    sched = Schedule(n_pages=8, requests=tuple(
        (tuple(int(t) for t in
               np.random.default_rng(i).integers(0, VOCAB_DRAW, p)), g)
        for i, (p, g) in enumerate(
            [(9, 6), (12, 6), (6, 6), (11, 5), (7, 6), (10, 5)])))
    stats = run_schedule(sched)
    assert stats["preemptions"] >= 1, stats
    assert stats["grown_pages"] >= 1, stats


def test_fuzz_forced_cow_in_place_parity():
    """Regression for the CoW/preemption crash: with the pool EXACTLY
    full (parent 3 pages + filler 4 = 7 usable), the exact duplicate
    admits by pure adoption (0 fresh pages), so when the parent's first
    decode write hits the shared tail page, ``_cow`` finds no free page
    and ``_ensure_free`` preempts the duplicate — the page's only
    co-holder — before ``cow_page`` runs.  ``cow_page`` then returns
    ``None`` (uniquely held again); the engine must write in place, not
    unpack the ``None`` and crash.  Token parity must still hold."""
    rng = np.random.default_rng(3)
    parent = tuple(int(t) for t in rng.integers(0, VOCAB_DRAW, 10))
    filler = tuple(int(t) for t in rng.integers(0, VOCAB_DRAW, 16))
    sched = Schedule(n_pages=8, requests=(
        # 3 pages; gen 3 keeps the parent decoding into its tail page
        # for one iteration AFTER the duplicate adopts (gen 2 would
        # retire it the same iteration it registers, emptying the index
        # before the duplicate's next admission attempt)
        (parent, 3),
        (filler, 1),      # 4 pages: fills the pool, prefills 2 chunks
        (parent, 2),      # admitted by adoption once the parent registers
    ))
    stats = run_schedule(sched)
    assert stats["prefix_hit_pages"] >= 3, stats
    assert stats["cow_in_place"] >= 1, stats
    assert stats["preemptions"] >= 2, stats   # duplicate, then filler


def test_fuzz_forced_cow_fork_parity():
    """Deterministic pin of the CoW guarantee: a duplicate admitted
    after its parent's prefill has registered must adopt the parent's
    pages (prefix hit) and break the shared last page with a
    copy-on-write fork before rewriting its final prompt token."""
    base = tuple(int(t) for t in
                 np.random.default_rng(7).integers(0, VOCAB_DRAW, 12))
    sched = Schedule(n_pages=16, requests=(
        # parent decodes long enough to stay alive while the dups land
        (base, 6),
        # two budget-1 fillers occupy the other slots and retire at
        # their own prefill, so the duplicate is admitted only AFTER
        # the parent's last chunk has registered its pages
        ((5, 6, 7), 1),
        ((8, 9, 10), 1),
        (base, 6),                       # exact duplicate -> CoW
        (base + (3, 1, 4), 5),           # extension -> pure prefix hits
    ))
    stats = run_schedule(sched)
    assert stats["prefix_hit_pages"] >= 3, stats
    assert stats["cow_copies"] >= 1, stats
