"""Substrate tests: data determinism, checkpoint atomicity/resharding,
optimizer correctness, gradient compression, monitor behavior."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import gsc_batch, lm_batch
from repro.optim import (AdamWConfig, apply_updates, dequantize_int8,
                         global_norm, init_state, quantize_int8,
                         warmup_cosine)
from repro.runtime import LossGuard, StepMonitor, bubble_fraction


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    a = lm_batch(seed=7, step=42, batch=8, seq=32, vocab=1000)
    b = lm_batch(seed=7, step=42, batch=8, seq=32, vocab=1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(seed=7, step=43, batch=8, seq=32, vocab=1000)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_shards_disjoint():
    full = lm_batch(seed=1, step=5, batch=8, seq=16, vocab=100)
    s0 = lm_batch(seed=1, step=5, batch=8, seq=16, vocab=100, shard=0,
                  n_shards=2)
    s1 = lm_batch(seed=1, step=5, batch=8, seq=16, vocab=100, shard=1,
                  n_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), full["tokens"])


def test_data_has_learnable_structure():
    b = lm_batch(seed=0, step=0, batch=64, seq=128, vocab=512)
    t = b["tokens"]
    linked = (np.roll(t, 1, axis=1) * 31 + 7) % 512
    frac = (t == linked).mean()
    assert 0.15 < frac < 0.4  # the 25% bigram dependency is present


def test_gsc_data_class_structure():
    b = gsc_batch(seed=0, step=0, batch=32)
    assert b["x"].shape == (32, 32, 32, 1)
    assert set(np.unique(b["y"])) <= set(range(12))
    # class pattern rows carry extra energy
    c = int(b["y"][0])
    f1 = (3 * c + 2) % 32
    assert b["x"][0, f1].mean() > b["x"][0].mean()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int8)},
            "step": jnp.asarray(3)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 10, tree, extra={"note": "x"})
    step, restored, extra = ckpt.restore_latest(d, tree)
    assert step == 10 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_ignores_uncommitted(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 5, _tree())
    # simulate a crash mid-write: a tmp dir without the .done marker
    os.makedirs(os.path.join(d, "step_00000009"))
    assert ckpt.latest_step(d) == 5


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    path = ckpt.save(d, 3, tree)
    shard = os.path.join(path, "shard_p0.npz")
    data = dict(np.load(shard))
    data["leaf_00000"] = data["leaf_00000"] + 1.0  # corrupt
    np.savez(shard, **data)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(d, 3, tree)


def test_checkpoint_prune(tmp_path):
    d = str(tmp_path)
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, _tree())
    ckpt.prune(d, keep=2)
    assert ckpt.list_steps(d) == [4, 5]


def test_checkpoint_reshard_restore(tmp_path):
    """Save under one mesh sharding, restore under another (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    if jax.device_count() < 1:
        pytest.skip("no devices")
    d = str(tmp_path)
    mesh1 = make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(64.0).reshape(8, 8)
    tree = {"w": jax.device_put(x, NamedSharding(mesh1, P("data", None)))}
    ckpt.save(d, 1, tree)
    # restore onto a different PartitionSpec
    sh2 = {"w": NamedSharding(mesh1, P(None, "model"))}
    _, restored, _ = ckpt.restore_latest(d, tree, sh2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
    assert restored["w"].sharding.spec == P(None, "model")


def test_checkpoint_async(tmp_path):
    d = str(tmp_path)
    t = ckpt.save_async(d, 7, _tree())
    t.join(timeout=10)
    assert ckpt.latest_step(d) == 7


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "route": jnp.zeros((2,), jnp.int8)}
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, grad_clip=100.0)
    state = init_state(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2), allow_int=True)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    # int leaves untouched
    np.testing.assert_array_equal(np.asarray(params["route"]),
                                  np.zeros(2, np.int8))


def test_adamw_bf16_moments_close_to_fp32():
    def run(moment_dtype):
        params = {"w": jnp.full((4,), 2.0)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, moment_dtype=moment_dtype)
        state = init_state(params, cfg)
        for _ in range(50):
            grads = {"w": params["w"] * 2.0}
            params, state, _ = apply_updates(params, grads, state, cfg)
        return np.asarray(params["w"])

    np.testing.assert_allclose(run(jnp.bfloat16), run(jnp.float32),
                               atol=0.05)


def test_grad_clip_norm():
    g = {"a": jnp.full((10,), 10.0)}
    from repro.optim import clip_by_global_norm
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 30


def test_warmup_cosine_shape():
    vals = [float(warmup_cosine(jnp.asarray(s), 10, 100)) for s in range(100)]
    assert vals[0] < 0.2
    assert abs(vals[10] - 1.0) < 0.1
    assert vals[99] < 0.5
    assert max(vals) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quant_roundtrip_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51


def test_error_feedback_unbiased_accumulation():
    """Over many steps, EF-compressed sums track the true sums (the
    residual guarantees no systematic bias)."""
    rng = np.random.default_rng(1)
    resid = jnp.zeros((64,))
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for step in range(100):
        g = jnp.asarray(rng.normal(size=64) * 0.01)
        comp_in = g + resid
        q, s = quantize_int8(comp_in)
        sent = dequantize_int8(q, s)
        resid = comp_in - sent
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual bounds the accumulated divergence
    assert np.abs(total_true - total_sent).max() <= float(np.abs(np.asarray(resid)).max()) + 1e-6


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------

def test_step_monitor_flags_stragglers():
    m = StepMonitor(straggler_factor=2.0, warmup_steps=2, trip_after=3)
    for i in range(10):
        m.record(i, 0.1)
    assert not m.should_reshard
    evs = [m.record(10 + i, 1.0) for i in range(3)]
    assert all(e.flagged for e in evs)
    assert m.should_reshard
    assert m.summary()["flagged"] == 3


def test_loss_guard():
    g = LossGuard(spike_factor=5.0)
    assert g.check(2.0)
    assert g.check(1.9)
    assert not g.check(float("nan"))
    assert not g.check(100.0)
    assert g.check(1.8)


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0
