"""Distribution tests on a small multi-device CPU mesh.

conftest.py pins XLA_FLAGS to 8 host devices for the test session (small,
so smoke tests stay fast) — these tests exercise real GSPMD partitioning,
shard_map pipeline parallelism, and compressed gradient sync.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import TrainConfig, get_config
from repro.data import batch_for
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, zero1_specs
from repro.models import init_model, loss_fn
from repro.optim import init_state, init_residuals, make_compressed_grad_sync
from repro.runtime import pipeline_apply
from repro.sharding import make_rules, param_sharding, use_rules

needs_devices = pytest.mark.skipif(jax.device_count() < 8,
                                   reason="needs 8 fake CPU devices")


class _Shape:
    seq_len = 32
    global_batch = 4


@needs_devices
def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,2) mesh and a (1,1) mesh must produce
    identical losses and parameters — SPMD correctness end to end."""
    cfg = get_config("smollm_360m").reduced(n_heads=4, n_kv_heads=2)
    tcfg = TrainConfig(lr=1e-3, zero1=True)
    batch_np = batch_for(cfg, _Shape, step=0)

    def run(mesh_dims):
        mesh = make_mesh(mesh_dims, ("data", "model"))
        rules = make_rules(mesh, "train")
        with use_rules(rules):
            params, specs = init_model(jax.random.PRNGKey(0), cfg)
            p_shard = param_sharding(specs, params, rules)
            params = jax.device_put(params, p_shard)
            train_step, acfg = make_train_step(cfg, tcfg)
            opt = init_state(params, acfg)
            batch = {k: jax.device_put(
                jnp.asarray(v),
                rules.sharding_for(("batch",) + (None,) * (v.ndim - 1),
                                   v.shape)) for k, v in batch_np.items()}
            params, opt, m = jax.jit(train_step)(params, opt, batch)
            leaves = [np.asarray(x, np.float32)
                      for x in jax.tree.leaves(params)
                      if jnp.issubdtype(x.dtype, jnp.floating)]
            return float(m["loss"]), leaves

    loss1, p1 = run((1, 1))
    loss2, p2 = run((2, 2))
    assert abs(loss1 - loss2) < 5e-3
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(a, b, atol=5e-3)


@needs_devices
def test_zero1_specs_shard_moments():
    cfg = get_config("smollm_360m").reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = make_rules(mesh, "train")
    with use_rules(rules):
        params, specs = init_model(jax.random.PRNGKey(0), cfg)
    z = zero1_specs(specs, params, rules)
    # the embed table spec gained a dp axis on a previously-None dim
    emb = z["embed"]["table"]
    assert ("data",) in emb or "data" in str(emb)


@needs_devices
def test_pipeline_parallel_matches_reference():
    mesh = make_mesh((4,), ("pipe",))
    n_stages, d = 4, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, d, d)) / np.sqrt(d)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
    y_pipe = pipeline_apply(stage_fn, mesh, "pipe", ws, x, n_micro=4)
    y_ref = x
    for i in range(n_stages):
        y_ref = stage_fn(ws[i], y_ref)
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               atol=1e-5)


@needs_devices
def test_compressed_grad_sync_cross_pod():
    """int8 EF sync over the pod axis ~= exact mean; residual holds the
    difference."""
    mesh = make_mesh((2, 4), ("pod", "data"))
    sync = make_compressed_grad_sync(mesh, "pod")
    rng = np.random.default_rng(0)
    g_global = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    # per-pod grads: place with pod-major sharding so pod p sees row p
    grads = {"w": jax.device_put(
        g_global, NamedSharding(mesh, P("pod", None)))}
    # trick: treat the (2, 64) array as per-pod rows; inside shard_map with
    # spec P() it would be full — instead emulate by calling sync on the
    # mean semantics directly:
    resid = init_residuals({"w": jnp.zeros((64,))}, n_pods=2)
    # feed per-pod values via the replicated-in path: each pod's local
    # value is its own row; emulate by running the local function under
    # shard_map with in_spec P('pod') for grads as well.
    from jax.sharding import PartitionSpec
    import jax as _jax

    def local(g, r):
        # g: (1, 64) this pod's grads; psum/EF inside
        from repro.optim.compression import _ef_psum_leaf
        out, r_new = _ef_psum_leaf(g[0], r[0], "pod", 2)
        return out[None], r_new[None]

    from repro.sharding.context import shard_map as _shard_map
    out, resid_new = _shard_map(
        local, mesh=mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod")), check_vma=False,
    )(grads["w"][:, :], resid["w"])
    # both pods converge to (approximately) the mean
    mean_true = np.asarray(g_global).mean(axis=0)
    got = np.asarray(out)
    np.testing.assert_allclose(got[0], mean_true, atol=0.05)
    np.testing.assert_allclose(got[0], got[1], atol=1e-6)


@needs_devices
def test_rules_divisibility_fallback():
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, "train")
    # 6 heads can't shard over model=4 -> replicated
    assert rules.spec_for(("batch", "heads"), (8, 6)) == P(("data",), None)
    assert rules.spec_for(("batch", "heads"), (8, 8)) == P(("data",), "model")
    # batch=1 can't shard over data -> replicated
    assert rules.spec_for(("batch", None), (1, 8)) == P(None, None)


@needs_devices
def test_decode_rules_shard_kv_seq():
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, "decode")
    spec = rules.spec_for(("batch", "kvseq", "kv", None), (8, 64, 4, 16))
    assert spec == P(("data",), "model", "kv" if False else None, None) or \
        spec[1] == "model"
    rules_long = make_rules(mesh, "decode_long")
    spec = rules_long.spec_for(("batch", "kvseq", None, None), (1, 64, 4, 16))
    assert spec[0] is None and spec[1] == ("data", "model")
