"""Telemetry-layer tests: histogram math, tracer nesting + JSONL schema,
scheduler lifecycle records, realized-sparsity accumulation, and the
disabled-mode no-op guarantee (telemetry stages nothing extra — same
Select count, bit-identical jaxpr — on the un-probed decode path)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.api import SparsityConfig
from repro.core.instrument import count_selects
from repro.models import transformer as T
from repro.obs import Telemetry
from repro.obs import sparsity as obs_sparsity
from repro.obs.export import (JsonlWriter, latency_columns,
                              sparsity_columns, validate_event,
                              validate_jsonl)
from repro.obs.metrics import (NULL_REGISTRY, Histogram, Registry,
                               RollingHistogram)
from repro.obs.sparsity import DispatchStats, SparsityStats
from repro.obs.trace import Tracer
from repro.runtime.monitor import LossGuard, StepMonitor
from repro.runtime.scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# metrics: counters / gauges / histogram math
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = Registry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("g")
    assert g.value is None
    g.set(7)
    g.set(4)
    assert g.value == 4.0
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 4.0


def test_histogram_bucketing_and_percentiles():
    import threading
    h = Histogram("h", "s", threading.Lock(), edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 8.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 8.0
    assert s["sum"] == pytest.approx(13.0)
    # target=2 lands at the end of bucket (1, 2] -> exactly the edge
    assert h.percentile(50.0) == pytest.approx(2.0)
    # everything above the last edge is clamped by the observed max
    assert h.percentile(100.0) == pytest.approx(8.0)


def test_histogram_single_bucket_exact():
    import threading
    h = Histogram("h", "s", threading.Lock(), edges=(1.0, 2.0))
    for _ in range(5):
        h.observe(0.25)
    # all mass in one bucket, min == max -> percentiles are exact
    assert h.percentile(50.0) == pytest.approx(0.25)
    assert h.percentile(99.0) == pytest.approx(0.25)


def test_histogram_empty_and_bad_inputs():
    reg = Registry()
    h = reg.histogram("h")
    assert h.snapshot() == {"count": 0}
    assert h.percentile(50.0) is None
    with pytest.raises(ValueError):
        h.percentile(101.0)
    with pytest.raises(ValueError):
        reg.histogram("bad_edges", edges=(2.0, 1.0))


def test_registry_idempotent_and_kind_mismatch():
    reg = Registry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_reset_keeps_handles():
    reg = Registry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(1.0)
    reg.reset()
    assert c.value == 0.0
    assert h.snapshot() == {"count": 0}
    c.inc()  # the old handle still feeds the registry
    assert reg.snapshot()["counters"]["c"] == 1.0


def test_disabled_registry_hands_out_shared_null():
    tel = Telemetry.off()
    a = tel.registry.counter("a")
    b = tel.registry.histogram("b")
    assert a is b  # one shared null singleton
    a.inc()
    b.observe(1.0)  # no-ops, no raise
    assert tel.registry.snapshot() == {"counters": {}, "gauges": {},
                                       "histograms": {}}
    assert NULL_REGISTRY.counter("z") is a


# ---------------------------------------------------------------------------
# rolling histogram: windowed percentiles with an injected clock
# ---------------------------------------------------------------------------

class _FakeClock:
    """Injectable monotonic clock the tests drive by hand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_rolling_histogram_window_expiry():
    import threading
    clk = _FakeClock()
    # window 6 s in 3 slices of 2 s
    h = RollingHistogram("r", "s", threading.Lock(), edges=(1.0, 2.0, 4.0),
                         window_s=6.0, n_slices=3, clock=clk)
    h.observe(0.5)          # slice epoch 0
    clk.t = 2.5
    h.observe(3.0)          # slice epoch 1
    assert h.count == 2     # both inside the window
    clk.t = 6.1             # epoch 3: slice 0's mass (epoch 0) expired
    assert h.count == 1
    assert h.snapshot()["min"] == pytest.approx(3.0)
    clk.t = 9.0             # past everything
    assert h.count == 0
    assert h.snapshot() == {"count": 0, "window_s": 6.0}
    assert h.percentile(95.0) is None


def test_rolling_histogram_merges_live_slices():
    import threading
    clk = _FakeClock()
    edges = (1.0, 2.0, 4.0)
    roll = RollingHistogram("r", "s", threading.Lock(), edges=edges,
                            window_s=6.0, n_slices=3, clock=clk)
    flat = Histogram("h", "s", threading.Lock(), edges=edges)
    # same observations spread across two live slices must merge to the
    # same percentile estimates the run-lifetime histogram computes
    for t, v in ((0.1, 0.5), (0.2, 1.5), (2.1, 3.0), (2.2, 8.0)):
        clk.t = t
        roll.observe(v)
        flat.observe(v)
    for q in (50.0, 95.0, 100.0):
        assert roll.percentile(q) == pytest.approx(flat.percentile(q))
    s = roll.snapshot()
    assert s["count"] == 4 and s["sum"] == pytest.approx(13.0)
    assert s["window_s"] == 6.0


def test_rolling_histogram_ring_reuses_slots():
    import threading
    clk = _FakeClock()
    h = RollingHistogram("r", "s", threading.Lock(), edges=(1.0,),
                         window_s=2.0, n_slices=2, clock=clk)
    # epoch 0 and epoch 2 share ring position 0: the stale epoch must be
    # zeroed when the slot is reused, not accumulated into
    h.observe(0.5)
    clk.t = 2.1             # epoch 2 evicts epoch 0 lazily on write
    h.observe(0.5)
    assert h.count == 1


def test_rolling_histogram_validation_and_reset():
    import threading
    lock = threading.Lock()
    with pytest.raises(ValueError):
        RollingHistogram("bad", "s", lock, edges=(2.0, 1.0))
    with pytest.raises(ValueError):
        RollingHistogram("bad", "s", lock, window_s=0.0)
    with pytest.raises(ValueError):
        RollingHistogram("bad", "s", lock, n_slices=0)
    h = RollingHistogram("r", "s", lock, edges=(1.0,), clock=_FakeClock())
    with pytest.raises(ValueError):
        h.percentile(-1.0)
    h.observe(0.5)
    h.reset()
    assert h.count == 0


def test_rolling_histogram_registry_accessor():
    reg = Registry()
    clk = _FakeClock()
    h = reg.rolling_histogram("w", window_s=10.0, n_slices=2, clock=clk)
    assert reg.rolling_histogram("w") is h  # idempotent per name
    with pytest.raises(TypeError):
        reg.histogram("w")  # kind mismatch with the plain histogram
    h.observe(0.01)
    snap = reg.snapshot()["histograms"]["w"]
    assert snap["count"] == 1 and snap["window_s"] == 10.0
    reg.reset()
    assert reg.snapshot()["histograms"]["w"] == {"count": 0,
                                                 "window_s": 10.0}
    # the disabled registry hands the shared null out here too
    assert NULL_REGISTRY.rolling_histogram("w").snapshot() is None


# ---------------------------------------------------------------------------
# tracer: nesting, totals, JSONL schema
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_totals():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner", uid=3):
            pass
        with tr.span("inner"):
            pass
    evs = list(tr.events)
    assert [e.name for e in evs] == ["inner", "inner", "outer"]
    assert evs[0].depth == 1 and evs[0].parent == "outer"
    assert evs[0].attrs == {"uid": 3}
    assert evs[2].depth == 0 and evs[2].parent is None
    tot = tr.totals()
    assert tot["inner"]["count"] == 2 and tot["outer"]["count"] == 1
    assert tot["outer"]["total_s"] >= tot["inner"]["total_s"]


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    with tr.span("a"):
        pass
    assert not tr.events and tr.totals() == {}
    # shared null span: no per-call allocation
    assert tr.span("a") is tr.span("b")


def test_tracer_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with JsonlWriter(path) as sink:
        tr = Tracer(enabled=True, sink=sink)
        with tr.span("outer"):
            with tr.span("inner", probed=True):
                pass
    n, errors = validate_jsonl(path)
    assert n == 2 and errors == []
    lines = [json.loads(x) for x in open(path)]
    assert lines[0]["name"] == "inner" and lines[0]["parent"] == "outer"


def test_validate_event_rejects_malformed():
    assert validate_event({"kind": "mystery"})
    assert validate_event({"kind": "span", "name": "x"})  # missing keys
    assert validate_event({"kind": "span", "name": "x", "ts": 0.0,
                           "dur_s": -1.0, "depth": 0, "parent": None})
    assert not validate_event({"kind": "span", "name": "x", "ts": 0.0,
                               "dur_s": 0.1, "depth": 0, "parent": None})


# ---------------------------------------------------------------------------
# scheduler lifecycle records (pure policy, no jax)
# ---------------------------------------------------------------------------

def test_scheduler_lifecycle_8_requests_4_slots(tmp_path):
    path = str(tmp_path / "req.jsonl")
    tel = Telemetry.on(jsonl_path=path)
    s = Scheduler(4, telemetry=tel)
    reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=3)
            for i in range(8)]
    s.submit_many(reqs, now=0.0)
    t = 0.0
    while s.has_work:
        t += 0.010
        for slot in s.admit(now=t):
            s.record_token(slot, 11, now=t)  # first token from prefill
        s.retire_done(now=t)
        t += 0.005
        for slot in s.active_slots():
            s.record_token(slot, 12, now=t)
        s.retire_done(now=t)
    tel.close()
    assert sorted(s.finished) == list(range(8))
    snap = tel.registry.snapshot()
    assert snap["counters"]["serve.requests_submitted"] == 8
    assert snap["counters"]["serve.requests_finished"] == 8
    assert snap["counters"]["serve.tokens_generated"] == 24
    assert snap["histograms"]["serve.ttft_s"]["count"] == 8
    assert snap["histograms"]["serve.itl_s"]["count"] == 16  # 2 itl/req
    # the second wave (uids 4-7) waited for slots; the first did not
    first = [s.records[i].queue_wait_s for i in range(4)]
    second = [s.records[i].queue_wait_s for i in range(4, 8)]
    assert max(first) < min(second)
    for rec in s.records.values():
        assert rec.n_tokens == 3
        assert rec.t_enqueue <= rec.t_admit <= rec.t_first_token \
            <= rec.t_finish
        assert validate_event(rec.to_event()) == []
    n, errors = validate_jsonl(path)
    assert errors == [] and n == 8  # one request event per retirement


def test_request_record_status_marks_in_flight():
    """A snapshot taken mid-serve reports queued/in-flight requests with
    their partial timings instead of dropping them (ISSUE 9 bugfix)."""
    s = Scheduler(1)
    reqs = [Request(uid=i, prompt=[1, 2], max_new_tokens=2)
            for i in range(2)]
    s.submit_many(reqs, now=0.0)
    assert {r.status for r in s.records.values()} == {"queued"}
    s.admit(now=0.1)
    # uid 0 occupies the only slot; uid 1 still queued
    assert s.records[0].status == "in_flight"
    assert s.records[1].status == "queued"
    ev = s.records[0].to_event()
    assert ev["status"] == "in_flight" and ev["t_finish"] == 0.0
    assert validate_event(ev) == []
    for slot in s.active_slots():
        s.record_token(slot, 5, now=0.2)
        s.record_token(slot, 5, now=0.3)
    s.retire_done(now=0.3)
    assert s.records[0].status == "finished"
    assert s.records[0].to_event()["status"] == "finished"
    assert s.records[1].status == "queued"  # untouched by retirement


# ---------------------------------------------------------------------------
# realized-sparsity accumulation
# ---------------------------------------------------------------------------

def _support(idx_rows, u=2, b=3, k=4):
    """(U, B, 1, K) vals/idx with all winners non-zero."""
    idx = np.broadcast_to(np.asarray(idx_rows, np.int32), (u, b, 1, k))
    vals = np.ones((u, b, 1, k), np.float32)
    return vals, np.array(idx)


def test_sparsity_stats_overlap_and_reset():
    st = SparsityStats()
    meta = {"ffn": {"d": 16, "kind": "support"}}
    st.update({"ffn": _support([0, 1, 2, 3])}, meta, active_rows=[0, 1, 2])
    st.update({"ffn": _support([0, 1, 2, 3])}, meta, active_rows=[0, 1, 2])
    sm = st.summary()
    assert set(sm) == {"ffn.u0", "ffn.u1"}
    e = sm["ffn.u0"]
    assert e["realized_k_frac"] == pytest.approx(4 / 16)
    assert e["winner_overlap"] == pytest.approx(1.0)  # identical supports
    assert e["k"] == 4 and e["d"] == 16
    # a fresh request in row 0 must not bridge overlap across requests
    st.reset_row(0)
    st.update({"ffn": _support([4, 5, 6, 7])}, meta, active_rows=[0, 1, 2])
    e = st.summary()["ffn.u0"]
    # rows 1,2 contribute 0.0 overlap (disjoint), row 0 is suppressed:
    # mean over (3 prev samples of 1.0) + (2 new of 0.0) = 3/5
    assert e["winner_overlap"] == pytest.approx(3 / 5)


def test_sparsity_stats_nnz_path():
    st = SparsityStats()
    nnz = np.full((2, 3, 1), 5, np.int32)  # (U, B, S=1)
    st.update({"ffn": (nnz,)}, {"ffn": {"d": 20, "kind": "nnz"}},
              active_rows=[0, 2])
    sm = st.summary()
    assert sm["ffn.u0"]["realized_k_frac"] == pytest.approx(5 / 20)
    assert "winner_overlap" not in sm["ffn.u0"]  # no index form
    assert "k" not in sm["ffn.u0"]


def test_dispatch_stats_seal_and_flop_shares():
    ds = DispatchStats()
    ds.on_event({"path": "topk", "batch": 4, "d_in": 512, "d_out": 128,
                 "n": 4, "k": 64, "pallas": False, "interpret": False})
    ds.on_event({"path": "hadamard", "batch": 4, "d_in": 128, "d_out": 512,
                 "n": 4, "pallas": False, "interpret": False})
    ds.seal()
    ds.on_event({"path": "dense", "batch": 4, "d_in": 8, "d_out": 8})
    out = ds.summary(decode_total_s=10.0)
    assert set(out["paths"]) == {"topk[jnp]", "hadamard[jnp]"}  # sealed
    topk = 2.0 * 4 * 64 * 128
    had = 2.0 * 4 * 128 * 512 / 4
    assert out["sparse_flop_frac_est"] == pytest.approx(
        topk / (topk + had), abs=1e-6)
    assert out["decode_sparse_time_est_s"] + \
        out["decode_dense_time_est_s"] == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# snapshot -> bench columns
# ---------------------------------------------------------------------------

def test_latency_and_sparsity_columns():
    reg = Registry()
    reg.histogram("serve.ttft_s").observe(0.1)
    snap = {
        "metrics": reg.snapshot(),
        "sparsity": {
            "layers": {"a": {"realized_k_frac": 0.1, "winner_overlap": 0.5},
                       "b": {"realized_k_frac": 0.3}},
            "paths": {"sparse_flop_frac_est": 0.25},
        },
    }
    lat = latency_columns(snap)
    assert lat["ttft_p50_ms"] == pytest.approx(100.0)
    assert "itl_p50_ms" not in lat  # absent histogram -> no columns
    sp = sparsity_columns(snap)
    assert sp["realized_k_frac"] == pytest.approx(0.2)
    assert sp["winner_overlap"] == pytest.approx(0.5)
    assert sp["sparse_flop_frac_est"] == 0.25


# ---------------------------------------------------------------------------
# monitor rides the registry
# ---------------------------------------------------------------------------

def test_step_monitor_feeds_registry():
    reg = Registry()
    m = StepMonitor(straggler_factor=2.0, warmup_steps=1, trip_after=2,
                    registry=reg)
    for i, dur in enumerate((0.1, 0.1, 1.0, 1.0)):
        m.record(i, dur)
    s = m.summary()
    assert s["steps"] == 4 and s["flagged"] == 2
    assert s["max_s"] == pytest.approx(1.0)
    assert s["ema_s"] == pytest.approx(m.ema)
    assert reg.snapshot()["histograms"]["monitor.step_s"]["count"] == 4
    assert m.should_reshard


def test_loss_guard_counts_rollbacks():
    reg = Registry()
    g = LossGuard(spike_factor=2.0, registry=reg)
    assert g.check(1.0)
    assert not g.check(float("nan"))
    assert not g.check(10.0)
    assert reg.snapshot()["counters"]["monitor.loss_rollbacks"] == 2


# ---------------------------------------------------------------------------
# disabled-mode no-op: telemetry stages nothing on the decode path
# ---------------------------------------------------------------------------

def _sparse_cfg():
    return get_config("smollm-360m").reduced(
        d_model=64, d_ff=256, vocab_size=128, n_heads=2, n_kv_heads=2,
        head_pad=0, compute_dtype="float32", param_dtype="float32",
        ffn_sparsity=SparsityConfig(n=4, k_frac=0.125))


def test_probe_adds_no_select_and_off_path_is_unchanged():
    cfg = _sparse_cfg()
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    cache, _ = T.init_cache(cfg, 2, 16)
    toks = jnp.ones((2, 1), jnp.int32)

    def plain(p, c, t):
        return T.serve_step(p, c, {"tokens": t}, 4, cfg)

    with count_selects() as c_off:
        jaxpr_before = str(jax.make_jaxpr(plain)(params, cache, toks))

    def probed(p, c, t):
        with obs_sparsity.capture_supports() as cap:
            logits, new_cache = T.serve_step(p, c, {"tokens": t}, 4, cfg)
        return logits, new_cache, cap.take_arrays()

    with count_selects() as c_on:
        probed_jaxpr = jax.make_jaxpr(probed)(params, cache, toks)
    # the probe returns the winner supports as extra outputs...
    n_plain_out = len(jax.make_jaxpr(plain)(
        params, cache, toks).jaxpr.outvars)
    assert len(probed_jaxpr.jaxpr.outvars) > n_plain_out
    # ...but stages NO extra Select: the supports are the ones the k-WTA
    # layers already computed (one top_k per sparse layer, unchanged)
    assert c_on.top_k == c_off.top_k > 0
    # and once the capture closes, the un-probed path re-traces
    # bit-identically: no state leaks from the probed trace
    jaxpr_after = str(jax.make_jaxpr(plain)(params, cache, toks))
    assert jaxpr_after == jaxpr_before
    assert obs_sparsity.drain_pending() == ()  # inactive capture: no-op


def test_engine_off_vs_on_same_tokens():
    # telemetry must never change what the engine generates
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import Engine
    cfg = _sparse_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(3)]
    out_off, _ = Engine(cfg, mesh, max_seq=16, n_slots=2).serve(reqs)
    tel = Telemetry.on(sparsity_every=1)
    eng = Engine(cfg, mesh, max_seq=16, n_slots=2, telemetry=tel)
    out_on, _ = eng.serve(reqs)
    assert out_off == out_on
    snap = eng.metrics_snapshot()
    assert snap["sparsity"]["layers"]  # probed run measured something
    assert snap["metrics"]["histograms"]["serve.ttft_s"]["count"] == 3
