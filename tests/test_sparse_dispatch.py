"""Sparse-sparse dispatch tests: the one-Select-per-layer handoff, the
batched topk_gather kernel vs the jnp formulas across layouts, the
backend-aware executor, and the kernel's argument validation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSLayout, SparsityConfig, choose_executor,
                        count_selects, cs_topk_from_support, cs_topk_matmul,
                        kwta, kwta_support, make_routes, pack_dense,
                        routes_to_mask, topk_support_flat)
from repro.core.layers import (apply_kwta, packed_linear_apply,
                               packed_linear_init)
from repro.kernels import (to_partition_major, topk_gather_matmul,
                           topk_gather_op, topk_gather_support_op,
                           topk_support)


def make_case(d_in, d_out, n, seed=0, route_share=1):
    lay = CSLayout(d_in, d_out, n)
    g = lay.groups
    r = g if route_share == 0 else min(route_share, g)
    while g % r:
        r -= 1
    route = make_routes(CSLayout(d_in, n * (g // r), n), seed)
    route_full = np.broadcast_to(
        route[:, None], (g // r, r, lay.partitions, n)).reshape(
        g, lay.partitions, n)
    rng = np.random.default_rng(seed + 1)
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    w = w * routes_to_mask(lay, route_full)
    packed = pack_dense(lay, w, route_full)
    return jnp.asarray(w), jnp.asarray(packed), jnp.asarray(route)


# ---------------------------------------------------------------------------
# batched kernel vs jnp formula: route sharing, batch regimes, block tiling
# ---------------------------------------------------------------------------

# route_share 0 = one table for all groups, 1 = faithful per-group,
# 99 >= G = per-group after the divisor fallback.
@pytest.mark.parametrize("route_share", [0, 1, 99])
@pytest.mark.parametrize("b", [1, 3, 8, 16])
def test_batched_kernel_matches_jnp_paths(route_share, b):
    """Interpret-mode batched topk_gather vs F.cs_topk_matmul vs the masked
    dense matmul, across route sharing and batch sizes straddling the
    B*K < D_in crossover (D_in=64, K=8: topk wins below B=8)."""
    d_in, d_out, n, k = 64, 32, 4, 8
    w, packed, route = make_case(d_in, d_out, n, seed=route_share + 1,
                                 route_share=route_share)
    x = jax.random.normal(jax.random.PRNGKey(b), (b, d_in))
    xs = kwta(x, k)
    y_jnp = cs_topk_matmul(xs, packed, route, k)
    vals, idx = topk_support_flat(xs, k)
    y_pl = topk_gather_support_op(vals, idx // n, idx % n, packed, route,
                                  True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(xs @ w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(xs @ w),
                               atol=1e-4)


def test_batched_kernel_block_g_tiling():
    """block_g < G sweeps the group grid dimension; results must not move."""
    d_in, d_out, n, k = 64, 64, 4, 8
    w, packed, route = make_case(d_in, d_out, n, seed=3)
    x = kwta(jax.random.normal(jax.random.PRNGKey(0), (4, d_in)), k)
    vals, p_idx, s_off = topk_support(x, k, n)
    pr, rr = to_partition_major(packed, route)
    full = topk_gather_matmul(vals, p_idx, s_off, pr, rr, interpret=True)
    for block_g in (1, 2, 4, 8):
        tiled = topk_gather_matmul(vals, p_idx, s_off, pr, rr,
                                   block_g=block_g, interpret=True)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(full),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(x @ w),
                               atol=1e-4)


def test_packed_linear_padded_bias_sliced_layout():
    """d_in/d_out not divisible by N: inputs zero-pad, outputs slice back to
    the bias length — identical on the jnp and forced-Pallas executors, and
    with/without the k-WTA support handoff."""
    d_in, d_out, n, k = 62, 30, 4, 8
    cfg = SparsityConfig(n=n, k_frac=k / d_in, path="topk")
    params, _ = packed_linear_init(jax.random.PRNGKey(0), d_in, d_out, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, d_in))
    h, support = apply_kwta(x, cfg, return_support=True)
    y_ref = packed_linear_apply(params, h,
                                dataclasses.replace(cfg, path="hadamard"))
    for use_pallas in ("off", "force"):
        cfg_x = dataclasses.replace(cfg, use_pallas=use_pallas)
        y_hand = packed_linear_apply(params, h, cfg_x, x_is_sparse=True,
                                     support=support)
        y_self = packed_linear_apply(params, h, cfg_x, x_is_sparse=True)
        assert y_hand.shape == (3, d_out)
        np.testing.assert_allclose(np.asarray(y_hand), np.asarray(y_ref),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_self), np.asarray(y_ref),
                                   atol=1e-4)


def test_auto_path_crossover_consistency():
    """path='auto' flips topk -> hadamard when B*K >= D_in; both sides of
    the crossover must agree with the masked dense matmul."""
    d_in, d_out, n, k = 64, 32, 4, 8
    cfg = SparsityConfig(n=n, k_frac=k / d_in)
    w, packed, route = make_case(d_in, d_out, n, seed=9)
    params = {"packed": packed, "route": route}
    for b in (2, 4, 8, 32):   # crossover at B*8 < 64 -> B < 8
        x = kwta(jax.random.normal(jax.random.PRNGKey(b), (b, d_in)), k)
        y = packed_linear_apply(params, x, cfg, x_is_sparse=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   atol=1e-4)


def test_support_op_handles_leading_batch_dims():
    """The serving shape (B, S=1, D) flattens to one kernel launch."""
    d_in, d_out, n, k = 64, 32, 4, 8
    w, packed, route = make_case(d_in, d_out, n, seed=5)
    x = kwta(jax.random.normal(jax.random.PRNGKey(2), (4, 1, d_in)), k)
    vals, idx = topk_support_flat(x, k)
    y = topk_gather_support_op(vals, idx // n, idx % n, packed, route, True)
    assert y.shape == (4, 1, d_out)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4)


# ---------------------------------------------------------------------------
# one Select per sparse layer (the Fig. 8a pipeline contract)
# ---------------------------------------------------------------------------

def test_ffn_issues_exactly_one_topk_per_layer():
    from repro.models.ffn import ffn_apply, ffn_init
    cfg_sp = SparsityConfig(n=4, k_frac=0.125)
    params, _ = ffn_init(jax.random.PRNGKey(0), 64, 256, cfg_sp)
    x = jnp.zeros((2, 1, 64))
    with count_selects() as c:
        jax.make_jaxpr(lambda x: ffn_apply(params, x, cfg_sp))(x)
    assert c.top_k == 1, (
        "sparse-sparse FFN must run ONE Select: the k-WTA support is handed "
        "to the down projection instead of re-running top_k")


def test_serve_step_issues_one_topk_per_sparse_layer(lint_clean):
    """Decode through the whole transformer: exactly one top_k staged per
    sparse FFN in the scanned superblock (and none anywhere else)."""
    from repro.analysis import expected_selects
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-360m").reduced(
        d_model=64, d_ff=256, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_pad=0, compute_dtype="float32", param_dtype="float32",
        ffn_sparsity=SparsityConfig(n=4, k_frac=0.125))
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    cache, _ = T.init_cache(cfg, 2, 8)
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    pos = jnp.zeros((2,), jnp.int32)
    n_sparse_per_unit = sum(k == "attn" for k in cfg.block_pattern)
    with count_selects() as c:
        jax.make_jaxpr(lambda p, c, b, pos: T.serve_step(p, c, b, pos, cfg))(
            params, cache, batch, pos)
    assert c.top_k == n_sparse_per_unit
    # and the static analyzer agrees, layer by layer
    lint_clean(lambda p, c, b, q: T.serve_step(p, c, b, q, cfg),
               params, cache, batch, pos,
               expected=expected_selects(cfg, n_tokens=2))


def test_cs_topk_matmul_without_handoff_still_one_topk():
    """The standalone sparse-sparse matmul runs its own single Select."""
    _, packed, route = make_case(64, 32, 4)
    with count_selects() as c:
        jax.make_jaxpr(lambda x: cs_topk_matmul(x, packed, route, 8))(
            jnp.zeros((2, 64)))
    assert c.top_k == 1


def test_kwta_support_matches_kwta():
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 96))
    y, (vals, idx) = kwta_support(x, 12)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(kwta(x, 12)))
    np.testing.assert_allclose(
        np.asarray(jnp.take_along_axis(y, idx, axis=-1)), np.asarray(vals))
    # support consumed downstream reproduces the sparse-sparse product
    w, packed, route = make_case(96, 32, 4, seed=8)
    y_sup = cs_topk_from_support(vals, idx // 4, idx % 4, packed, route)
    np.testing.assert_allclose(np.asarray(y_sup), np.asarray(y @ w),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# executor selection (backend-aware; CPU test environment -> no real Pallas)
# ---------------------------------------------------------------------------

def test_choose_executor_modes():
    on_tpu = jax.default_backend() == "tpu"
    ex = choose_executor(SparsityConfig(use_pallas="off"))
    assert not ex.use_pallas
    ex = choose_executor(SparsityConfig(use_pallas="force"))
    assert ex.use_pallas and ex.interpret == (not on_tpu)
    ex = choose_executor(SparsityConfig(use_pallas="auto"))
    assert ex.use_pallas == on_tpu and not ex.interpret


# ---------------------------------------------------------------------------
# kernel argument validation (regression: the reversed divisibility error)
# ---------------------------------------------------------------------------

def _kernel_args(p=16, g=8, n=4, b=1, k=2):
    v = jnp.zeros((b, k))
    i = jnp.zeros((b, k), jnp.int32)
    return v, i, i, jnp.zeros((p, g, n)), jnp.zeros((p, g, n), jnp.int8)


def test_topk_gather_rejects_non_divisor_block_g():
    v, pi, so, pr, rr = _kernel_args()
    with pytest.raises(ValueError, match=r"block_g=3 must divide G=8"):
        topk_gather_matmul(v, pi, so, pr, rr, block_g=3)


def test_topk_gather_rejects_oversized_block_g():
    v, pi, so, pr, rr = _kernel_args()
    with pytest.raises(ValueError, match=r"block_g=16 exceeds G=8"):
        topk_gather_matmul(v, pi, so, pr, rr, block_g=16)


def test_topk_gather_rejects_empty_support():
    v, pi, so, pr, rr = _kernel_args(k=1)
    with pytest.raises(ValueError, match=r"k_nnz=0"):
        topk_gather_matmul(v[:, :0], pi[:, :0], so[:, :0], pr, rr)


# ---------------------------------------------------------------------------
# gradients: straight-through on the support, parity with the jnp path
# ---------------------------------------------------------------------------

def test_topk_gather_op_grad_parity_with_jnp():
    """Differentiating through the Pallas call (custom VJP) must equal the
    autodiff of cs_topk_matmul — gradients live only on the selected
    support, for both the packed weights and the input."""
    d_in, d_out, n, k = 128, 64, 4, 16
    _, packed, route = make_case(d_in, d_out, n, seed=11)
    x = kwta(jax.random.normal(jax.random.PRNGKey(4), (4, d_in)), k)

    def loss_pl(p, x):
        return jnp.sum(topk_gather_op(x, p, route, k, True) ** 2)

    def loss_jnp(p, x):
        return jnp.sum(cs_topk_matmul(x, p, route, k) ** 2)

    gp_pl, gx_pl = jax.grad(loss_pl, argnums=(0, 1))(packed, x)
    gp_j, gx_j = jax.grad(loss_jnp, argnums=(0, 1))(packed, x)
    np.testing.assert_allclose(np.asarray(gp_pl), np.asarray(gp_j),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gx_pl), np.asarray(gx_j),
                               rtol=1e-3, atol=1e-3)
    # input gradient is zero off the support
    off = np.asarray(x) == 0
    assert np.all(np.asarray(gx_pl)[off] == 0)


def test_topk_gather_op_grad_route_share():
    d_in, d_out, n, k = 64, 64, 4, 8
    _, packed, route = make_case(d_in, d_out, n, seed=13, route_share=0)
    x = kwta(jax.random.normal(jax.random.PRNGKey(5), (2, d_in)), k)
    gp_pl = jax.grad(lambda p: jnp.sum(
        topk_gather_op(x, p, route, k, True) ** 2))(packed)
    gp_j = jax.grad(lambda p: jnp.sum(
        cs_topk_matmul(x, p, route, k) ** 2))(packed)
    np.testing.assert_allclose(np.asarray(gp_pl), np.asarray(gp_j),
                               rtol=1e-3, atol=1e-3)
