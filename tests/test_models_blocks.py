"""Block-level model tests: flash==materialized attention, SSD scan vs
naive recurrence, MoE dispatch conservation, MLA cache equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import ssm as S
from repro.models.attention import _causal_attn, _flash_attn


def test_flash_equals_materialized():
    key = jax.random.PRNGKey(0)
    b, s, h, dh = 2, 64, 4, 16
    q, k, v = (jax.random.normal(kk, (b, s, h, dh))
               for kk in jax.random.split(key, 3))
    out_ref = _causal_attn(q, k, v, 0.25)
    for block in [8, 16, 32]:
        out = _flash_attn(q, k, v, 0.25, block)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   atol=1e-4)


def test_flash_unroll_equals_scan():
    key = jax.random.PRNGKey(1)
    q, k, v = (jax.random.normal(kk, (1, 32, 2, 8))
               for kk in jax.random.split(key, 3))
    a = _flash_attn(q, k, v, 0.3, 8, unroll=False)
    b = _flash_attn(q, k, v, 0.3, 8, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def _ssd_naive(q, k, v, log_a):
    """O(T) reference recurrence for the chunked SSD scan."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    S_ = np.zeros((b, h, dk, dv), np.float32)
    ys = []
    for i in range(t):
        a = np.exp(np.asarray(log_a[:, i], np.float32))[:, :, None, None]
        S_ = a * S_ + np.einsum("bhd,bhe->bhde", np.asarray(k[:, i]),
                                np.asarray(v[:, i]))
        ys.append(np.einsum("bhd,bhde->bhe", np.asarray(q[:, i]), S_))
    return np.stack(ys, axis=1), S_


@given(st.sampled_from([4, 8, 16]), st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_ssd_scan_matches_recurrence(chunk, seed):
    key = jax.random.PRNGKey(seed)
    b, t, h, dk, dv = 2, 32, 2, 4, 6
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, t, h, dk))
    k = jax.random.normal(ks[1], (b, t, h, dk))
    v = jax.random.normal(ks[2], (b, t, h, dv))
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, t, h))) * 0.1
    y, S_fin = S.ssd_scan(q, k, v, log_a, chunk)
    y_ref, S_ref = _ssd_naive(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-3)
    np.testing.assert_allclose(np.asarray(S_fin), S_ref, atol=2e-3)


def test_ssd_step_continues_scan():
    """decode step from the scan's final state == scan over T+1."""
    key = jax.random.PRNGKey(5)
    b, t, h, dk, dv = 1, 16, 2, 4, 4
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, t + 1, h, dk))
    k = jax.random.normal(ks[1], (b, t + 1, h, dk))
    v = jax.random.normal(ks[2], (b, t + 1, h, dv))
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, t + 1, h))) * 0.1
    y_full, _ = S.ssd_scan(q, k, v, log_a, chunk=t + 1)
    _, S_t = S.ssd_scan(q[:, :t], k[:, :t], v[:, :t], log_a[:, :t], chunk=t)
    y_step, _ = S.ssd_step(S_t, q[:, t], k[:, t], v[:, t], log_a[:, t])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, t]),
                               atol=2e-3)


def test_moe_conserves_tokens_and_balances():
    """Every kept token's output is the capacity-weighted expert mix; with
    generous capacity nothing drops and the combine is exact for a linear
    'expert'."""
    from repro.models.moe import moe_apply
    cfg = get_config("qwen3_moe_235b_a22b").reduced(
        n_experts=4, experts_per_token=2, capacity_factor=4.0)
    d, ff = cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(0)
    from repro.models.moe import moe_init
    params, _ = moe_init(key, d, ff, 4, 0, "silu", cfg.ffn_sparsity)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    y, aux = moe_apply(params, x, cfg, cfg.ffn_sparsity)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.5 < float(aux) < 10.0  # aux ~ 1 for near-uniform routing


def test_moe_group_vs_global_equivalence():
    """Grouped dispatch must compute the same function as a single-group
    dispatch when capacity is non-binding."""
    from repro.models.moe import moe_apply, moe_init
    cfg = get_config("qwen3_moe_235b_a22b").reduced(
        n_experts=4, experts_per_token=2, capacity_factor=8.0)
    d, ff = cfg.d_model, cfg.d_ff
    params, _ = moe_init(jax.random.PRNGKey(0), d, ff, 4, 0, "silu",
                         cfg.ffn_sparsity)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d), jnp.float32)
    y4, _ = moe_apply(params, x, cfg, cfg.ffn_sparsity)     # 4 groups
    y1, _ = moe_apply(params, x.reshape(1, 32, d), cfg, cfg.ffn_sparsity)
    np.testing.assert_allclose(np.asarray(y4).reshape(1, 32, d),
                               np.asarray(y1), atol=1e-4)


def test_mla_cache_decode_matches_full():
    cfg = get_config("deepseek_v2_lite_16b").reduced(
        remat=False, n_experts=0, n_shared_experts=0, experts_per_token=0,
        d_ff=64)
    from repro.models import forward, init_cache, init_model, serve_step
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    full, _ = forward(params, {"tokens": toks}, cfg)
    cache, _ = init_cache(cfg, 2, 8)
    for pos in range(8):
        logits, cache = serve_step(params, cache,
                                   {"tokens": toks[:, pos:pos + 1]}, pos, cfg)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=0.15, rtol=0.05)


def test_mla_cache_is_compressed():
    """MLA cache per token (r + rope_dim) must be much smaller than a GQA
    cache (2 * kv * dh) — the latent-compression claim."""
    cfg = get_config("deepseek_v2_lite_16b")
    mla_per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
    gqa_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    assert mla_per_tok * 7 < gqa_per_tok
