"""Test session setup: 8 fake CPU devices for the distribution tests.

NOTE: this is test-only. The dry-run sets its own 512-device flag in
repro/launch/dryrun.py (before any import), and production uses real
devices; smoke tests run fine under 8 devices because every sharding rule
falls back to replication when dims don't divide.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
