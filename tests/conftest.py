"""Test session setup: 8 fake CPU devices for the distribution tests.

NOTE: this is test-only. The dry-run sets its own 512-device flag in
repro/launch/dryrun.py (before any import), and production uses real
devices; smoke tests run fine under 8 devices because every sharding rule
falls back to replication when dims don't divide.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest


@pytest.fixture
def lint_clean():
    """Assert a callable stages zero sparsity findings, in one line:

        lint_clean(lambda p, x: ffn_apply(p, x, sp), params, x)

    Arguments may be concrete arrays or ShapeDtypeStructs; lint options
    (``expected=``, ``check_dense_fallback=``, ...) pass through to
    :func:`repro.analysis.lint_fn`.  Returns the report for further
    assertions."""
    from repro.analysis import lint_fn

    def check(fn, *args, **kwargs):
        report = lint_fn(fn, *args, **kwargs)
        assert report.ok, report.render()
        return report

    return check
