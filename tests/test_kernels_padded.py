"""Parity on padded / non-divisible shapes — the inputs the
``unmasked-pad`` rule guards.

The kernel wrappers refuse genuinely partial blocks at runtime (the
shared ``block_validation`` divisibility contract), so the sanctioned
way to run a non-divisible logical shape is pad-to-multiple → kernel →
slice — exactly the laundering the verifier models (a padded lane never
reaches the output unmasked, because the pad is zeros and the logical
region is sliced back out).  These tests pin both halves of that
contract for all four Pallas kernels: (a) the padded round-trip matches
the ``ref.py`` oracle on the *original* shape, and (b) the wrappers
reject the partial shape itself with the uniform divisibility error."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSLayout, kwta, make_routes, pack_dense, routes_to_mask
from repro.kernels import (grouped_cs_matmul, kwta_hist_pallas,
                           packed_matmul, permute_activations,
                           to_partition_major, topk_gather_matmul,
                           topk_support)
from repro.kernels import ref as R


def make_case(d_in, d_out, n, seed=0, dtype=np.float32):
    lay = CSLayout(d_in, d_out, n)
    route = make_routes(lay, seed)
    rng = np.random.default_rng(seed + 1)
    w = rng.normal(size=(d_in, d_out)).astype(dtype)
    w = w * routes_to_mask(lay, route).astype(dtype)
    packed = pack_dense(lay, w, route)
    return jnp.asarray(w), jnp.asarray(packed), jnp.asarray(route)


def _pad_axis(x, axis, to):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# packed_matmul: batch 6 over block_b=4 — trailing batch block is partial
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,block_b", [(6, 4), (3, 2), (10, 8)])
def test_packed_matmul_padded_batch(b, block_b):
    d_in, d_out, n = 64, 64, 4
    w, packed, route = make_case(d_in, d_out, n, seed=3)
    pr, rr = to_partition_major(packed, route)
    x = jax.random.normal(jax.random.PRNGKey(0), (b, d_in))
    with pytest.raises(ValueError, match="must divide"):
        packed_matmul(x, pr, rr, block_b=block_b, block_p=8, block_g=8,
                      interpret=True)
    b_pad = -(-b // block_b) * block_b
    y = packed_matmul(_pad_axis(x, 0, b_pad), pr, rr, block_b=block_b,
                      block_p=8, block_g=8, interpret=True)[:b]
    y_ref = R.ref_packed_matmul(x, packed, route)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# grouped_cs_matmul: batch axis of the (N, B, P) slot-major layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,block_b", [(6, 4), (5, 4)])
def test_grouped_padded_batch(b, block_b):
    d_in, d_out, n = 64, 32, 4
    route_s = make_routes(CSLayout(d_in, n, n), seed=4)     # shared route
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d_in))
    xg = permute_activations(x, route_s)
    pk = jax.random.normal(jax.random.PRNGKey(2), (n, d_in // n, d_out // n))
    with pytest.raises(ValueError, match="must divide"):
        grouped_cs_matmul(xg, pk, block_b=block_b, block_p=8, block_g=8,
                          interpret=True)
    b_pad = -(-b // block_b) * block_b
    y = grouped_cs_matmul(_pad_axis(xg, 1, b_pad), pk, block_b=block_b,
                          block_p=8, block_g=8, interpret=True)[:, :b]
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(R.ref_grouped_cs_matmul(xg, pk)),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# topk_gather_matmul: group axis — pad packed/route G with zero groups
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d_out,g_extra,block_g", [(24, 2, 4), (20, 1, 2)])
def test_topk_gather_padded_groups(d_out, g_extra, block_g):
    d_in, n, b, k = 64, 4, 4, 8
    w, packed, route = make_case(d_in, d_out, n, seed=7)
    pr, rr = to_partition_major(packed, route)      # (P, G, N), G = 6
    g = pr.shape[1]
    assert g % block_g, "case must exercise a non-divisible G"
    xs = kwta(jax.random.normal(jax.random.PRNGKey(3), (b, d_in)), k)
    vals, pidx, soff = topk_support(xs, k, n)
    with pytest.raises(ValueError, match="must divide"):
        topk_gather_matmul(vals, pidx, soff, pr, rr, block_g=block_g,
                           interpret=True)
    # Pad G to a block multiple with zero weight groups: padded routes are
    # 0, but their packed values are 0, so any spurious "hit" adds 0.
    g_pad = g + g_extra
    assert g_pad % block_g == 0
    y = topk_gather_matmul(vals, pidx, soff, _pad_axis(pr, 1, g_pad),
                           _pad_axis(rr, 1, g_pad), block_g=block_g,
                           interpret=True)
    # kernel output interleaves groups as (B, nG tiles of block_g*N):
    # slicing the logical region back out means dropping the zero groups
    y = y.reshape(b, g_pad, n)[:, :g].reshape(b, g * n)
    y_ref = R.ref_topk_gather(vals, pidx, soff, pr, rr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xs @ w), atol=1e-4)


# ---------------------------------------------------------------------------
# kwta_hist: batch rows over block_b — padded rows are all-zero rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,block_b", [(6, 4), (7, 4)])
def test_kwta_hist_padded_batch(b, block_b):
    d, k = 128, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (b, d))
    with pytest.raises(ValueError, match="must divide"):
        kwta_hist_pallas(x, k, block_b=block_b, interpret=True)
    b_pad = -(-b // block_b) * block_b
    y = kwta_hist_pallas(_pad_axis(x, 0, b_pad), k, block_b=block_b,
                         interpret=True)[:b]
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(R.ref_kwta_hist(x, k)))
