"""launch/hlo.py tests against a checked-in HLO text fixture.

The fixture (tests/fixtures/sample_module.hlo) is a hand-written but
syntactically faithful HLO module containing: a while loop whose body
stages an all-reduce, an f8e4m3fn all-gather inside a fusion, a
reduce-scatter and an async all-gather-start/done pair at top level,
plus one of each host-transfer shape (send/send-done, a MoveToHost
custom call, a copy into host memory space S(5)).  Every byte total
below is computed by hand from the fixture's shapes."""

import os

from repro.analysis import lint_hlo
from repro.launch.hlo import (collective_bytes, collective_stats,
                              count_hlo_ops, host_transfer_ops,
                              parse_computations, while_body_computations)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "sample_module.hlo")


def _text():
    with open(FIXTURE) as f:
        return f.read()


def test_parse_computations_segments_module():
    comps = parse_computations(_text())
    assert {"add_f32", "fused_collective", "body.1", "cond.1",
            "main.42", "ENTRY"} <= set(comps)
    assert comps["ENTRY"] is comps["main.42"]
    assert any("while(" in line for line in comps["main.42"])
    assert any("all-reduce(" in line for line in comps["body.1"])


def test_while_body_computations_transitive():
    in_while = while_body_computations(_text())
    assert "body.1" in in_while and "cond.1" in in_while
    # all-reduce's to_apply inside the body is reached transitively
    assert "add_f32" in in_while
    # the fusion is called from ENTRY, not from the while body
    assert "fused_collective" not in in_while


def test_collective_bytes_totals_with_f8():
    stats = collective_bytes(_text())
    # all-reduce: bf16[2,1024] = 4096 B (the -done-skip rule is N/A here)
    assert stats["all-reduce_bytes"] == 4096
    assert stats["all-reduce_count"] == 1
    # all-gather: f8e4m3fn[4,128] = 512 B (1 B/elt)  +  async
    # all-gather-start f32[16,256] = 16384 B; the -done twin is skipped.
    assert stats["all-gather_bytes"] == 512 + 16384
    assert stats["all-gather_count"] == 2
    # reduce-scatter: f32[4,256] = 4096 B
    assert stats["reduce-scatter_bytes"] == 4096
    assert stats["total_bytes"] == 4096 + 512 + 16384 + 4096


def test_collective_stats_while_body_accounting():
    stats = collective_stats(_text())
    # flat totals match collective_bytes
    assert stats["all-reduce_bytes"] == 4096
    assert stats["all-gather_bytes"] == 512 + 16384
    # only the all-reduce sits inside the while body (runs once per trip)
    assert stats["all-reduce_in_while_count"] == 1
    assert stats["all-reduce_in_while_bytes"] == 4096
    assert "all-gather_in_while_count" not in stats
    assert "reduce-scatter_in_while_count" not in stats


def test_host_transfer_ops_census():
    kinds = [k for k, _ in host_transfer_ops(_text())]
    assert kinds.count("send") == 1
    assert kinds.count("send-done") == 1
    assert kinds.count("MoveToHost") == 1
    assert kinds.count("host-space-copy") == 1
    assert len(kinds) == 4


def test_count_hlo_ops_census():
    ops = count_hlo_ops(_text())
    assert ops["while"] == 1
    assert ops["fusion"] == 1


def test_hlo_rule_pack_on_fixture():
    report = lint_hlo(_text(), entry="decode")
    assert len(report.by_rule("hlo-host-transfer")) == 4
    flagged = {f.primitive for f in report.by_rule("hlo-collective")}
    assert flagged == {"all-reduce", "all-gather", "reduce-scatter"}
    # the in-while accounting surfaces in the message
    ar = [f for f in report.by_rule("hlo-collective")
          if f.primitive == "all-reduce"][0]
    assert "1 inside while bodies" in ar.message
    # allowed kinds are not findings
    report2 = lint_hlo(_text(), entry="decode",
                       allowed_collectives=("all-reduce", "all-gather",
                                            "reduce-scatter"))
    assert report2.by_rule("hlo-collective") == []


def test_clean_hlo_reports_nothing():
    clean = """HloModule jit_step

ENTRY %main.1 (p0.0: f32[8,8]) -> f32[8,8] {
  %p0.0 = f32[8,8]{1,0} parameter(0)
  ROOT %r = f32[8,8]{1,0} add(f32[8,8]{1,0} %p0.0, f32[8,8]{1,0} %p0.0)
}
"""
    assert lint_hlo(clean).ok
    assert host_transfer_ops(clean) == []
    assert collective_stats(clean) == {}
