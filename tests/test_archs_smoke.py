"""Per-architecture smoke tests: reduced same-family configs run one
forward + one train step + one decode step on CPU, asserting shapes and
finiteness. (Full configs are exercised via the dry-run only.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.data import batch_for
from repro.launch.steps import make_train_step
from repro.models import init_cache, init_model, loss_fn, serve_step
from repro.optim import init_state


class _Shape:
    seq_len = 32
    global_batch = 2


def _batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in batch_for(cfg, _Shape, step=0).items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params, specs = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert 0 < float(loss) < 20

    train_step, acfg = make_train_step(cfg, TrainConfig(lr=1e-3))
    opt = init_state(params, acfg)
    params2, opt2, m = jax.jit(train_step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(opt2["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params2),
                                jax.tree.leaves(params))
                if jnp.issubdtype(a.dtype, jnp.floating))
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    b = 2
    cache, _ = init_cache(cfg, b, 16)
    if cfg.frontend == "embed":
        batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                             (b, 1, cfg.d_model),
                                             jnp.bfloat16)}
    else:
        batch = {"tokens": jnp.ones((b, 1), jnp.int32)}
    logits, cache2 = serve_step(params, cache, batch, 3, cfg)
    assert logits.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache was actually updated
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(bb, np.float32))
        for a, bb in zip(jax.tree.leaves(cache2), jax.tree.leaves(cache)))
    assert changed, f"{arch}: decode did not update any cache"


def test_decode_matches_forward_smollm():
    """Step-by-step decode must reproduce the full forward's logits
    (KV-cache correctness, the serving-path invariant)."""
    cfg = get_config("smollm_360m").reduced(remat=False)
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size)
    from repro.models import forward
    full_logits, _ = forward(params, {"tokens": toks}, cfg)
    cache, _ = init_cache(cfg, 2, 8)
    for pos in range(8):
        logits, cache = serve_step(params, cache,
                                   {"tokens": toks[:, pos:pos + 1]}, pos, cfg)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32), atol=0.15, rtol=0.05)


def test_decode_matches_forward_ssm():
    """Same invariant for the recurrent-state (SSM) cache path."""
    cfg = get_config("xlstm_350m").reduced(remat=False)
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    from repro.models import forward
    full_logits, _ = forward(params, {"tokens": toks}, cfg)
    cache, _ = init_cache(cfg, 2, 16)
    for pos in range(16):
        logits, cache = serve_step(params, cache,
                                   {"tokens": toks[:, pos:pos + 1]}, pos, cfg)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32), atol=0.15, rtol=0.05)


def test_head_padding_exactness():
    """head_pad must not change the function (dummy heads are masked)."""
    import dataclasses
    cfg = get_config("smollm_360m").reduced(remat=False, n_heads=3,
                                            n_kv_heads=1, head_pad=0)
    cfg_pad = dataclasses.replace(cfg, head_pad=4)
    params, _ = init_model(jax.random.PRNGKey(3), cfg)
    params_pad, _ = init_model(jax.random.PRNGKey(3), cfg_pad)
    # copy the unpadded o-proj rows into the padded one; dummy rows zeroed

    def fix(tree_pad, tree):
        for i in range(cfg.n_units):
            pass
        return tree_pad

    # instead: run the padded config with o rows beyond h*dh zeroed is
    # guaranteed by masking; compare logits for identical q/k/v weights by
    # copying all weights whose shapes match and padding o with zeros.
    def match(pp, p):
        out = {}
        for k, v in pp.items():
            if isinstance(v, dict):
                out[k] = match(v, p[k])
            elif v.shape == p[k].shape:
                out[k] = p[k]
            else:  # o-proj (..., hp*dh, d) vs (..., h*dh, d): zero-pad rows
                pw = [(0, 0)] * v.ndim
                pw[-2] = (0, v.shape[-2] - p[k].shape[-2])
                out[k] = jnp.pad(p[k], pw)
        return out

    params_pad = match(params_pad, params)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                              cfg.vocab_size)
    from repro.models import forward
    l1, _ = forward(params, {"tokens": toks}, cfg)
    l2, _ = forward(params_pad, {"tokens": toks}, cfg_pad)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=2e-2)
