"""Property + unit tests for complementary mask generation and packing."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CSLayout, make_routes, packed_bytes, pack_dense,
                        routes_to_mask, unpack, validate_complementary)

LAYOUTS = st.tuples(
    st.sampled_from([2, 4, 8, 16]),          # n
    st.integers(1, 8),                        # partitions
    st.integers(1, 8),                        # groups
    st.sampled_from(["random", "cyclic"]),
    st.integers(0, 2 ** 31 - 1),              # seed
)


@given(LAYOUTS)
@settings(max_examples=60, deadline=None)
def test_routes_are_complementary(args):
    n, p, g, kind, seed = args
    lay = CSLayout(p * n, g * n, n, kind)
    route = make_routes(lay, seed)
    validate_complementary(lay, route)  # permutation per (g, p)


@given(LAYOUTS)
@settings(max_examples=40, deadline=None)
def test_mask_density_and_overlay(args):
    """The paper's core structural claim: N sparse structures with density
    1/N tile the dense structure exactly (no collisions, no gaps)."""
    n, p, g, kind, seed = args
    lay = CSLayout(p * n, g * n, n, kind)
    mask = routes_to_mask(lay, make_routes(lay, seed))
    # each output column has exactly P = d_in/N non-zeros -> density 1/N
    assert (mask.sum(axis=0) == lay.partitions).all()
    # within each group, every input position is owned exactly once
    for gi in range(lay.groups):
        cols = mask[:, gi * n:(gi + 1) * n]
        assert (cols.sum(axis=1) == 1).all()


@given(LAYOUTS)
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(args):
    n, p, g, kind, seed = args
    lay = CSLayout(p * n, g * n, n, kind)
    route = make_routes(lay, seed)
    rng = np.random.default_rng(seed % 1000)
    w = rng.normal(size=(lay.d_in, lay.d_out)).astype(np.float32)
    w = w * routes_to_mask(lay, route)
    packed = pack_dense(lay, w, route)
    assert packed.shape == (lay.groups, lay.partitions, n)
    np.testing.assert_array_equal(unpack(lay, packed, route), w)


def test_bad_route_rejected():
    lay = CSLayout(8, 8, 4)
    route = make_routes(lay, 0).copy()
    route[0, 0, 0] = route[0, 0, 1]  # introduce a collision
    with pytest.raises(ValueError, match="collide"):
        validate_complementary(lay, route)


def test_layout_validation():
    with pytest.raises(ValueError):
        CSLayout(10, 8, 4)  # d_in not divisible
    with pytest.raises(ValueError):
        CSLayout(8, 10, 4)  # d_out not divisible


def test_compression_accounting():
    lay = CSLayout(1600, 1500 + 4, 4)  # GSC-like linear, padded
    acct = packed_bytes(lay)
    # N-fold weight compression, modest route overhead
    assert acct["packed_weight_bytes"] * 4 == acct["dense_bytes"]
    assert 2.5 < acct["compression_random"] < 4.0
    # cyclic routes cost 1 byte per N^2 weights -> closer to the ideal N
    assert acct["compression_random"] < acct["compression_cyclic"] <= 4.0


def test_cyclic_routes_are_shifts():
    lay = CSLayout(32, 16, 4, "cyclic")
    route = make_routes(lay, 7).astype(np.int64)
    diffs = (route - route[..., :1]) % 4
    np.testing.assert_array_equal(diffs, np.broadcast_to(np.arange(4), route.shape))
