"""Serving-engine tests: scheduler slot admission/retirement, sampling,
fused-prefill correctness, and continuous-batching parity against the
static-batch oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import Engine, _bucket
from repro.models import transformer as T
from repro.runtime.scheduler import (Request, SamplingParams, Scheduler,
                                     sample_token)


# ---------------------------------------------------------------------------
# scheduler (pure policy, no jax)
# ---------------------------------------------------------------------------

def _req(uid, p_len=4, max_new=8, **kw):
    return Request(uid=uid, prompt=list(range(p_len)),
                   max_new_tokens=max_new, **kw)


def test_scheduler_fifo_admission():
    s = Scheduler(2)
    s.submit_many([_req(0), _req(1), _req(2)])
    admitted = s.admit()
    assert [sl.request.uid for sl in admitted] == [0, 1]
    assert [sl.index for sl in admitted] == [0, 1]
    assert s.admit() == []           # no free slots
    assert [r.uid for r in s.queue] == [2]
    assert s.has_work


def test_scheduler_positions_start_at_prompt_len():
    s = Scheduler(1)
    s.submit(_req(7, p_len=5))
    (slot,) = s.admit()
    assert slot.pos == 5 and slot.generated == []


def test_scheduler_retire_frees_slot_and_readmits():
    s = Scheduler(1)
    s.submit_many([_req(0, max_new=2), _req(1, max_new=1)])
    (slot,) = s.admit()
    s.record_token(slot, 11)
    assert not slot.done
    s.record_token(slot, 12)
    assert slot.done
    retired = s.retire_done()
    assert [r.request.uid for r in retired] == [0]
    assert s.finished[0] == [11, 12]
    assert not s.slots[0].busy
    (slot2,) = s.admit()              # the queued request takes the slot
    assert slot2.request.uid == 1 and slot2.index == 0
    s.record_token(slot2, 3)
    s.retire_done()
    assert s.finished[1] == [3]
    assert not s.has_work


def test_scheduler_eos_retires_early():
    s = Scheduler(1)
    s.submit(_req(0, max_new=100, eos_id=42))
    (slot,) = s.admit()
    s.record_token(slot, 5)
    s.record_token(slot, 42)
    assert slot.done
    s.retire_done()
    assert s.finished[0] == [5, 42]


def test_sampling_greedy_and_topk():
    logits = np.asarray([0.0, 5.0, 1.0, 4.0])
    assert sample_token(logits, SamplingParams(), None) == 1
    rng = np.random.default_rng(0)
    picks = {sample_token(logits, SamplingParams(temperature=1.0, top_k=2),
                          rng) for _ in range(50)}
    assert picks <= {1, 3}            # top-2 filter holds
    assert len(picks) == 2            # and it actually samples
    # per-request seeds are deterministic
    a = [sample_token(logits, SamplingParams(temperature=0.7, seed=3),
                      np.random.default_rng(3)) for _ in range(5)]
    b = [sample_token(logits, SamplingParams(temperature=0.7, seed=3),
                      np.random.default_rng(3)) for _ in range(5)]
    assert a == b


def test_bucket_is_pow2_and_capped():
    assert _bucket(3, 64) == 8
    assert _bucket(9, 64) == 16
    assert _bucket(16, 64) == 16
    assert _bucket(60, 32) == 32


# ---------------------------------------------------------------------------
# fused prefill == stepwise prefill (the tentpole's correctness claim)
# ---------------------------------------------------------------------------

def _cfg(**overrides):
    base = dict(head_pad=0, compute_dtype="float32", param_dtype="float32")
    base.update(overrides)
    return get_config("smollm-360m").reduced(**base)


def test_fused_prefill_matches_stepwise_cache():
    cfg = _cfg()
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    b, p_len, max_seq = 2, 7, 24
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (b, p_len)).astype(np.int32)
    logits_f, cache_f = jax.jit(
        lambda p, t: T.prefill(p, {"tokens": t}, cfg, max_seq))(
            params, jnp.asarray(toks))
    cache_s, _ = T.init_cache(cfg, b, max_seq)
    logits_s = None
    for pos in range(p_len):
        logits_s, cache_s = T.serve_step(
            params, cache_s, {"tokens": jnp.asarray(toks[:, pos:pos + 1])},
            pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_f[:, p_len - 1]),
                               np.asarray(logits_s), atol=1e-4)
    for lf, ls in zip(jax.tree.leaves(cache_f), jax.tree.leaves(cache_s)):
        # only rows [0, p_len) are defined; later rows are scratch
        np.testing.assert_allclose(
            np.asarray(lf, np.float32)[:, :, :p_len],
            np.asarray(ls, np.float32)[:, :, :p_len], atol=1e-4)


def test_fused_prefill_rejects_ssm_patterns():
    cfg = get_config("zamba2-1p2b")
    assert not T.supports_fused_prefill(cfg)
    assert T.supports_fused_prefill(_cfg())


def test_decode_vector_positions_match_scalar():
    """A (B,) position vector with equal entries must equal the scalar-pos
    decode — the continuous-batching kernel contract."""
    cfg = _cfg()
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    b, max_seq = 3, 16
    cache, _ = T.init_cache(cfg, b, max_seq)
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size, (b, 1))
    for pos in range(4):
        batch = {"tokens": jnp.asarray(toks)}
        l1, c1 = T.serve_step(params, cache, batch, pos, cfg)
        l2, c2 = T.serve_step(params, cache, batch,
                              jnp.full((b,), pos, jnp.int32), cfg)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
        for a, bb in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(bb, np.float32), atol=1e-5)
        cache = c1


# ---------------------------------------------------------------------------
# engine: continuous batching vs the static-batch oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    cfg = _cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    return Engine(cfg, mesh, max_seq=48, n_slots=4)


def test_continuous_matches_static_greedy(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (4, 9)).astype(np.int32)
    static = engine.generate_static(prompts, 12)
    out, stats = engine.serve(
        [Request(uid=i, prompt=prompts[i].tolist(), max_new_tokens=12)
         for i in range(4)])
    for i in range(4):
        np.testing.assert_array_equal(static[i], np.asarray(out[i]))
    assert stats["decode_steps"] == 11          # first token from prefill
    assert len(stats["ttft_s"]) == 4


def test_one_prefill_call_per_prompt(engine):
    """The fused prefill issues ONE compiled call per prompt — not one per
    position (the seed's behavior)."""
    before = engine.prefill_calls
    prompts = np.random.default_rng(2).integers(
        0, engine.cfg.vocab_size, (3, 9)).astype(np.int32)
    engine.serve([Request(uid=i, prompt=prompts[i].tolist(),
                          max_new_tokens=4) for i in range(3)])
    assert engine.prefill_calls - before == 3
    # every prompt in this module pads to the same 16-token bucket, so
    # jit's shape-keyed cache holds exactly one prefill executable
    cache_size = getattr(engine._prefill_jit, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 1


def test_slot_reuse_midflight_matches_oracle(engine):
    """More requests than slots with mixed budgets: freed slots are
    refilled mid-flight and every request still matches the oracle."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, engine.cfg.vocab_size, 9).astype(np.int32)
               for _ in range(6)]
    budgets = [3, 8, 5, 13, 2, 7]
    out, stats = engine.serve(
        [Request(uid=i, prompt=prompts[i].tolist(),
                 max_new_tokens=budgets[i]) for i in range(6)])
    assert sorted(out) == list(range(6))
    for i in range(6):
        ref = engine.generate_static(prompts[i][None, :], budgets[i])
        np.testing.assert_array_equal(ref[0], np.asarray(out[i]))


def test_engine_rejects_oversized_request(engine):
    with pytest.raises(ValueError, match="exceeds max_seq"):
        engine.serve([Request(uid=0, prompt=[1] * 40, max_new_tokens=40)])


def test_prefill_boundary_rejects_oversized_prompt(engine):
    """_prefill called directly (outside serve()'s validation) must raise
    rather than silently truncate the prompt to the max_seq bucket."""
    with pytest.raises(ValueError, match="refusing to truncate"):
        engine._prefill(list(range(engine.max_seq + 1)))


# ---------------------------------------------------------------------------
# sparse-sparse decode through the batched Pallas kernel (interpret on CPU)
# ---------------------------------------------------------------------------

def _sparse_engine(use_pallas):
    from repro.core.api import SparsityConfig
    cfg = _cfg(d_ff=256,
               ffn_sparsity=SparsityConfig(n=4, k_frac=0.125))
    mesh = make_mesh((1, 1), ("data", "model"))
    return Engine(cfg, mesh, max_seq=32, n_slots=4, use_pallas=use_pallas)


def test_sparse_sparse_continuous_matches_static_with_pallas():
    """Continuous batching through the batched topk_gather kernel (forced,
    interpret fallback on CPU) must match both the static-batch oracle and
    the jnp-executor engine token-for-token."""
    eng_pl = _sparse_engine("force")
    assert eng_pl.cfg.ffn_sparsity.use_pallas == "force"
    prompts = np.random.default_rng(5).integers(
        0, eng_pl.cfg.vocab_size, (4, 9)).astype(np.int32)
    reqs = lambda: [Request(uid=i, prompt=prompts[i].tolist(),  # noqa: E731
                            max_new_tokens=10) for i in range(4)]
    out_pl, stats = eng_pl.serve(reqs())
    static = eng_pl.generate_static(prompts, 10)
    eng_jnp = _sparse_engine("off")
    out_jnp, _ = eng_jnp.serve(reqs())
    for i in range(4):
        np.testing.assert_array_equal(static[i], np.asarray(out_pl[i]))
        np.testing.assert_array_equal(np.asarray(out_jnp[i]),
                                      np.asarray(out_pl[i]))
    assert stats["decode_steps"] == 9
