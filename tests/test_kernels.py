"""Per-kernel validation: interpret=True Pallas execution vs ref.py oracles,
swept over shapes, dtypes, pack factors and block configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSLayout, kwta, make_routes, pack_dense, routes_to_mask
from repro.kernels import (grouped_cs_matmul, grouped_cs_matmul_op,
                           kwta_hist_op, kwta_hist_pallas, packed_matmul,
                           packed_matmul_op, permute_activations,
                           to_partition_major, topk_gather_matmul,
                           topk_gather_op, topk_support)
from repro.kernels import ref as R


def make_case(d_in, d_out, n, seed=0, dtype=np.float32):
    lay = CSLayout(d_in, d_out, n)
    route = make_routes(lay, seed)
    rng = np.random.default_rng(seed + 1)
    w = rng.normal(size=(d_in, d_out)).astype(dtype)
    w = w * routes_to_mask(lay, route).astype(dtype)
    packed = pack_dense(lay, w, route)
    return jnp.asarray(w), jnp.asarray(packed), jnp.asarray(route)


SWEEP = [
    # (B, d_in, d_out, n, dtype, blocks)
    (8, 64, 64, 2, jnp.float32, (8, 8, 8)),
    (16, 128, 64, 4, jnp.float32, (8, 16, 16)),
    (16, 256, 256, 4, jnp.bfloat16, (8, 32, 32)),
    (32, 256, 128, 8, jnp.float32, (16, 16, 16)),
    (8, 512, 256, 16, jnp.bfloat16, (8, 16, 8)),
]


@pytest.mark.parametrize("b,d_in,d_out,n,dtype,blocks", SWEEP)
def test_packed_matmul_kernel(b, d_in, d_out, n, dtype, blocks):
    w, packed, route = make_case(d_in, d_out, n)
    packed = packed.astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(0), (b, d_in), dtype)
    pr, rr = to_partition_major(packed, route)
    bb, bp, bg = blocks
    y = packed_matmul(x, pr, rr, block_b=bb, block_p=bp, block_g=bg,
                      interpret=True)
    y_ref = R.ref_packed_matmul(x, packed, route)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,d_in,d_out,n,dtype,blocks", SWEEP)
def test_grouped_kernel(b, d_in, d_out, n, dtype, blocks):
    route_s = make_routes(CSLayout(d_in, n, n), seed=4)  # shared (1, P, N)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d_in), dtype)
    xg = permute_activations(x, route_s)
    pk = jax.random.normal(jax.random.PRNGKey(2), (n, d_in // n, d_out // n),
                           dtype)
    bb, bp, bg = blocks
    y = grouped_cs_matmul(xg, pk, block_b=bb, block_p=bp, block_g=bg,
                          interpret=True)
    y_ref = R.ref_grouped_cs_matmul(xg, pk)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,d_in,d_out,n,k", [
    (1, 64, 64, 2, 8),
    (4, 128, 64, 4, 16),
    (8, 256, 128, 8, 16),
    (2, 256, 256, 4, 64),
])
def test_topk_gather_kernel(b, d_in, d_out, n, k):
    w, packed, route = make_case(d_in, d_out, n, seed=7)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, d_in))
    xs = kwta(x, k)
    vals, pidx, soff = topk_support(xs, k, n)
    pr, rr = to_partition_major(packed, route)
    y = topk_gather_matmul(vals, pidx, soff, pr, rr, interpret=True)
    y_ref = R.ref_topk_gather(vals, pidx, soff, pr, rr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    # and both equal the dense-masked matmul on the k-sparse input
    np.testing.assert_allclose(np.asarray(y), np.asarray(xs @ w), atol=1e-4)


@pytest.mark.parametrize("b,d,k,block_b", [
    (4, 256, 16, 4), (8, 512, 50, 8), (16, 1500, 180, 8), (2, 128, 1, 2),
])
def test_kwta_hist_kernel(b, d, k, block_b):
    x = jax.random.normal(jax.random.PRNGKey(4), (b, d))
    y = kwta_hist_pallas(x, k, block_b=block_b, interpret=True)
    y_ref = R.ref_kwta_hist(x, k)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    nz = np.asarray((y != 0).sum(-1))
    assert (nz >= k).all()


def test_kwta_hist_gsc_shape():
    """The paper's running example: 1500-element activation, 85% sparsity
    (Fig. 10: K = 225)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 1500))
    y = kwta_hist_pallas(x, 225, interpret=True)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(R.ref_kwta_hist(x, 225)))


def test_packed_matmul_op_grads():
    w, packed, route = make_case(128, 64, 4, seed=9)
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 128))

    def f(p, x):
        return jnp.sum(packed_matmul_op(x, p, route, True) ** 2)

    gp, gx = jax.grad(f, argnums=(0, 1))(packed, x)
    gw, gx_ref = jax.grad(lambda wd, x: jnp.sum((x @ wd) ** 2),
                          argnums=(0, 1))(w, x)
    lay = CSLayout(128, 64, 4)
    gp_ref = pack_dense(lay, np.asarray(gw), np.asarray(route))
    np.testing.assert_allclose(np.asarray(gp), gp_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               rtol=1e-3, atol=1e-3)


def test_grouped_op_grads():
    n, b, p, g = 4, 8, 32, 16
    xg = jax.random.normal(jax.random.PRNGKey(7), (n, b, p))
    pk = jax.random.normal(jax.random.PRNGKey(8), (n, p, g))

    def f(pk):
        return jnp.sum(grouped_cs_matmul_op(xg, pk, True) ** 2)

    gp = jax.grad(f)(pk)
    gp_ref = jax.grad(lambda pk: jnp.sum(
        jnp.einsum("nbp,npg->nbg", xg, pk) ** 2))(pk)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gp_ref),
                               rtol=1e-4, atol=1e-4)


def test_topk_gather_op_end_to_end():
    w, packed, route = make_case(256, 128, 4, seed=11)
    x = kwta(jax.random.normal(jax.random.PRNGKey(9), (4, 256)), 32)
    y = topk_gather_op(x, packed, route, 32, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4)


def test_kwta_hist_op_grad_straight_through():
    x = jnp.asarray([[0.9, 0.1, 0.5, 0.2, 0.8, 0.05, 0.3, 0.6]])
    g = jax.grad(lambda x: jnp.sum(kwta_hist_op(x, 3, True)))(x)
    y = kwta_hist_op(x, 3, True)
    np.testing.assert_array_equal(np.asarray(g != 0), np.asarray(y != 0))
