"""Tests for the three CS execution paths: equivalence to the masked dense
matmul, gradient correctness, and the paper's FLOP-saving claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CSLayout, SparsityConfig, cs_matmul, cs_matmul_dense,
                        cs_topk_matmul, decompress, kwta, make_routes,
                        routes_to_mask, pack_dense)
from repro.core.layers import (packed_linear_apply, packed_linear_from_dense,
                               packed_linear_init)


def make_case(d_in, d_out, n, seed=0, route_share=1):
    lay = CSLayout(d_in, d_out, n)
    g = lay.groups
    r = min(route_share, g)
    while g % r:
        r -= 1
    route = make_routes(CSLayout(d_in, n * (g // r), n), seed)
    route_full = np.broadcast_to(
        route[:, None], (g // r, r, lay.partitions, n)).reshape(g, lay.partitions, n)
    rng = np.random.default_rng(seed + 1)
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    w = w * routes_to_mask(lay, route_full)
    packed = pack_dense(lay, w, route_full)
    return jnp.asarray(w), jnp.asarray(packed), jnp.asarray(route)


CASES = st.tuples(
    st.sampled_from([(32, 16, 2), (64, 32, 4), (64, 64, 8), (128, 32, 16)]),
    st.integers(1, 4),   # batch rows
    st.integers(0, 99),  # seed
)


@given(CASES)
@settings(max_examples=30, deadline=None)
def test_paths_match_dense(args):
    (d_in, d_out, n), b, seed = args
    w, packed, route = make_case(d_in, d_out, n, seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32))
    y_ref = x @ w
    np.testing.assert_allclose(cs_matmul(x, packed, route), y_ref, atol=1e-4)
    np.testing.assert_allclose(cs_matmul_dense(x, packed, route), y_ref,
                               atol=1e-4)
    np.testing.assert_allclose(decompress(packed, route), w, atol=0)


@given(CASES, st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_route_share_paths_match(args, share):
    (d_in, d_out, n), b, seed = args
    w, packed, route = make_case(d_in, d_out, n, seed, route_share=share)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32))
    np.testing.assert_allclose(cs_matmul(x, packed, route), x @ w, atol=1e-4)


@given(CASES)
@settings(max_examples=20, deadline=None)
def test_topk_exact_on_ksparse(args):
    """Sparse-sparse path is exact whenever the input is k-sparse (the k-WTA
    contract) — the paper's rendezvous of non-zero activations with non-zero
    weights loses nothing."""
    (d_in, d_out, n), b, seed = args
    w, packed, route = make_case(d_in, d_out, n, seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32))
    k = max(1, d_in // 8)
    xs = kwta(x, k)
    np.testing.assert_allclose(cs_topk_matmul(xs, packed, route, k), xs @ w,
                               atol=1e-4)


def test_batched_leading_dims():
    w, packed, route = make_case(64, 32, 4)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 64)),
                    dtype=jnp.float32)
    y = cs_matmul(x, packed, route)
    assert y.shape == (2, 3, 32)
    np.testing.assert_allclose(y, x @ w, atol=1e-4)


def test_gradients_match_masked_dense():
    """Packed-weight gradients == dense gradients sampled on the CS support;
    input gradients match the dense layer's. Training with the sparse path is
    exactly masked-dense training (paper §4) at 1/N cost."""
    w, packed, route = make_case(64, 32, 4, seed=3)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(5, 64)),
                    dtype=jnp.float32)
    t = jnp.asarray(np.random.default_rng(4).normal(size=(5, 32)),
                    dtype=jnp.float32)

    def loss_packed(p, x):
        return jnp.mean((cs_matmul(x, p, route) - t) ** 2)

    def loss_dense(wd, x):
        return jnp.mean((x @ wd - t) ** 2)

    gp, gx = jax.grad(loss_packed, argnums=(0, 1))(packed, x)
    gw, gx_ref = jax.grad(loss_dense, argnums=(0, 1))(w, x)
    lay = CSLayout(64, 32, 4)
    route_np = np.asarray(route)
    gp_ref = pack_dense(lay, np.asarray(gw), route_np)
    np.testing.assert_allclose(gp, gp_ref, atol=1e-5)
    np.testing.assert_allclose(gx, gx_ref, atol=1e-5)


def test_flop_savings_in_hlo():
    """The compiled faithful path must cost ~1/N of dense FLOPs (the paper's
    central efficiency claim, checked on the actual XLA artifact)."""
    b, d_in, d_out, n = 64, 512, 512, 8
    w, packed, route = make_case(d_in, d_out, n, route_share=d_out // n)
    x = jax.ShapeDtypeStruct((b, d_in), jnp.float32)
    sparse = jax.jit(lambda x: cs_matmul(x, packed, route)).lower(x).compile()
    dense = jax.jit(lambda x: x @ w).lower(x).compile()
    from repro.launch.hlo import compiled_flops
    fs = compiled_flops(sparse)
    fd = compiled_flops(dense)
    assert fs < fd / (n / 2), f"sparse {fs} vs dense {fd}: less than {n/2}x saving"


def test_layer_init_and_paths():
    cfg = SparsityConfig(n=4, k_frac=0.125)
    key = jax.random.PRNGKey(0)
    params, specs = packed_linear_init(key, 64, 32, cfg)
    assert params["packed"].shape == (8, 16, 4)
    assert specs["packed"][0] == "mlp"
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    for path in ["hadamard", "dense"]:
        y = packed_linear_apply(params, x, SparsityConfig(n=4, path=path))
        assert y.shape == (4, 32) and not jnp.isnan(y).any()
    # topk path on k-sparse input agrees with hadamard path
    xs = kwta(x, 8)
    cfg_t = SparsityConfig(n=4, k_frac=8 / 64, path="topk")
    y_t = packed_linear_apply(params, xs, cfg_t, x_is_sparse=True)
    y_h = packed_linear_apply(params, xs, SparsityConfig(n=4, path="hadamard"))
    np.testing.assert_allclose(y_t, y_h, atol=1e-4)


def test_from_dense_roundtrip_apply():
    rng = np.random.default_rng(0)
    cfg = SparsityConfig(n=4)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    params = packed_linear_from_dense(w, cfg, seed=9)
    # apply only sees the masked projection of w
    from repro.core import unpack
    lay = CSLayout(64, 32, 4)
    r = np.asarray(params["route"])
    w_masked = unpack(lay, np.asarray(params["packed"]), r)
    x = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    y = packed_linear_apply(params, x, cfg)
    np.testing.assert_allclose(y, x @ jnp.asarray(w_masked), atol=1e-4)
