"""Graceful fallback when ``hypothesis`` is not installed.

The property-test modules import ``given/settings/st`` from here instead of
from ``hypothesis`` directly, so collection never hard-errors on a bare
environment (the seed suite died with 4 collection errors): with hypothesis
present the real decorators are re-exported; without it every ``@given``
test is skipped at run time while the plain unit tests in the same modules
still run.  ``pip install -r requirements-dev.txt`` restores the full
property suite.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare environments
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(see requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any strategy expression
        (st.integers(1, 8), st.sampled_from([...]).map(f), ...) evaluates
        without error; the tests using it are skipped anyway."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
