"""Static-analysis tests (repro.analysis): zero findings on the clean
sparse-sparse paths, seeded regressions caught (doubled Select, f64 in
the kernel input), the Select-count model, taint propagation, the shared
Pallas resource rule, and CLI exit codes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (Finding, Report, expected_selects,
                            family_selects, layer_key, lint_config, lint_fn,
                            propagate_taint, rule_pallas_resource,
                            seeded_regressions, self_test)
from repro.analysis.__main__ import main as cli_main
from repro.configs import get_config
from repro.core.api import SparsityConfig


def _smollm_reduced():
    return get_config("smollm_360m").reduced()


# ---------------------------------------------------------------------------
# Zero findings on the current sparse-sparse paths
# ---------------------------------------------------------------------------

def test_decode_prefill_zero_findings():
    report = lint_config(_smollm_reduced(), entries=("decode", "prefill"),
                         check_hlo=False)
    assert "decode" in report.entries and "prefill" in report.entries
    assert report.ok, report.render()


def test_decode_hlo_zero_findings():
    """AOT-compile the reduced decode step; the compiled module must stage
    no host transfers and no collectives (single-process)."""
    report = lint_config(_smollm_reduced(), entries=("decode",),
                         check_hlo=True)
    assert "decode:hlo" in report.entries
    assert report.ok, report.render()


def test_kernel_and_train_zero_findings():
    report = lint_config(_smollm_reduced(), entries=("kernel", "train"),
                         check_hlo=False)
    assert report.ok, report.render()


def test_lint_fn_fixture_one_liner(lint_clean):
    """The conftest fixture asserts zero findings in one line."""
    sp = SparsityConfig(n=4, k_frac=0.125)
    from repro.models.ffn import ffn_apply, ffn_init
    params = jax.eval_shape(
        lambda: ffn_init(jax.random.PRNGKey(0), 64, 256, sp)[0])
    x = jax.ShapeDtypeStruct((2, 1, 64), jnp.float32)
    lint_clean(lambda p, x: ffn_apply(p, x, sp), params, x,
               expected={"ffn": 1})


# ---------------------------------------------------------------------------
# Seeded regressions: the linter must catch what it claims to
# ---------------------------------------------------------------------------

def test_double_topk_regression_caught():
    report = seeded_regressions()["double-topk"]()
    found = report.by_rule("select-count")
    assert found, report.render()
    f = found[0]
    assert f.scope == "b0_attn/ffn"          # names the layer
    assert f.primitive == "top_k"            # names the primitive
    assert "2 Select" in f.message and "expected 1" in f.message


def test_f64_regression_caught():
    report = seeded_regressions()["f64-kernel"]()
    found = report.by_rule("dtype-promotion")
    assert found, report.render()
    assert any("ffn_down" in f.scope for f in found)
    assert any("float64" in f.message for f in found)


def test_self_test_catches_everything():
    assert self_test() == []


# ---------------------------------------------------------------------------
# The Select-count model
# ---------------------------------------------------------------------------

def test_family_selects_mirrors_dispatch():
    base = dict(n=4, k_frac=0.125, route_share=0)
    # bisect k-WTA stages no top_k; the topk-path projection re-derives.
    assert family_selects(SparsityConfig(kwta_impl="bisect", **base),
                          4, 128, 64) == 1
    # large batch leaves the topk regime: no Select at all.
    assert family_selects(SparsityConfig(kwta_impl="bisect", **base),
                          64, 128, 64) == 0
    # exact global top-k: one Select, support handed off (no re-derive).
    assert family_selects(SparsityConfig(kwta_impl="topk", **base),
                          4, 128, 64) == 1
    # local k-WTA has no handoff form: its Select + the re-derivation.
    assert family_selects(SparsityConfig(kwta_impl="topk",
                                         kwta_partitions=2, **base),
                          4, 128, 64) == 2
    # dense activations: nothing to Select.
    assert family_selects(SparsityConfig(n=4), 4, 128, 64) == 0


def test_expected_selects_layer_keys_and_moe_skip():
    exp = expected_selects(_smollm_reduced(), n_tokens=4)
    assert exp == {"b0_attn/ffn": 1, "b1_attn/ffn": 1}
    assert expected_selects(get_config("deepseek_v2_lite_16b"), 4) is None


def test_layer_key_collapses_paths():
    assert layer_key("b0_attn/ffn_down/cs_topk/select") == "b0_attn/ffn"
    assert layer_key("b1_attn/o_proj/select") == "b1_attn/o_proj"
    assert layer_key("b1_attn/transpose") == "b1_attn"
    assert layer_key("softmax") == ""


# ---------------------------------------------------------------------------
# Taint propagation (the dense-fallback engine)
# ---------------------------------------------------------------------------

def test_taint_flags_dot_on_select_support():
    def bad(x, w):
        vals, _ = jax.lax.top_k(x, 4)
        return vals @ w

    closed = jax.make_jaxpr(bad)(jnp.zeros((2, 8)), jnp.zeros((4, 3)))
    _, hits = propagate_taint(closed, ("top_k",), ("pallas_call",),
                              ("dot_general",))
    assert len(hits) == 1 and hits[0].eqn.primitive.name == "dot_general"


def test_taint_stops_at_sink_and_clean_inputs_pass():
    def clean(x, w):
        jax.lax.top_k(x, 4)      # support derived but never consumed
        return x @ w

    closed = jax.make_jaxpr(clean)(jnp.zeros((2, 8)), jnp.zeros((8, 3)))
    _, hits = propagate_taint(closed, ("top_k",), ("pallas_call",),
                              ("dot_general",))
    assert hits == []


def test_taint_crosses_scan_boundaries():
    def scanned(x, w):
        vals, _ = jax.lax.top_k(x, 4)

        def body(carry, _):
            return carry @ w, None

        y, _ = jax.lax.scan(body, vals, jnp.arange(3))
        return y

    closed = jax.make_jaxpr(scanned)(jnp.zeros((2, 8)), jnp.zeros((4, 4)))
    _, hits = propagate_taint(closed, ("top_k",), ("pallas_call",),
                              ("dot_general",))
    assert len(hits) >= 1


# ---------------------------------------------------------------------------
# Pallas resource rule (shared validator on staged BlockSpecs)
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def test_pallas_resource_vmem_budget():
    from jax.experimental import pallas as pl

    def big(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    x = jax.ShapeDtypeStruct((2048, 2048), jnp.float32)   # 16 MiB per buffer
    closed = jax.make_jaxpr(big)(x)
    findings = rule_pallas_resource(closed, entry="kernel")
    assert any("VMEM" in f.message for f in findings), findings


def test_pallas_resource_clean_kernel():
    from repro.kernels.ops import topk_gather_support_op

    vals = jax.ShapeDtypeStruct((2, 8), jnp.float32)
    idx = jax.ShapeDtypeStruct((2, 8), jnp.int32)
    packed = jax.ShapeDtypeStruct((16, 16, 4), jnp.float32)
    route = jax.ShapeDtypeStruct((16, 16, 4), jnp.int32)
    closed = jax.make_jaxpr(
        lambda v, i, s, p, r: topk_gather_support_op(v, i, s, p, r, True))(
        vals, idx, idx, packed, route)
    assert rule_pallas_resource(closed, entry="kernel") == []


# ---------------------------------------------------------------------------
# Waivers
# ---------------------------------------------------------------------------

def test_waivers_by_rule_and_scope():
    f1 = Finding(rule="select-count", message="m", scope="b0_attn/ffn")
    f2 = Finding(rule="dense-fallback", message="m", scope="b1_attn/ffn")
    r = Report()
    r.add([f1, f2], waivers=("select-count:b0_attn",))
    assert [f.rule for f in r.findings] == ["dense-fallback"]
    assert r.waived == [f1]
    assert not r.ok
    r2 = Report()
    r2.add([f1, f2], waivers=("select-count", "dense-fallback"))
    assert r2.ok and len(r2.waived) == 2


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------

def test_cli_clean_config_exits_zero(capsys):
    rc = cli_main(["--config", "smollm_360m", "--reduced", "--no-hlo",
                   "--fail-on-findings"])
    out = capsys.readouterr().out
    assert rc == 0 and "clean: 0 findings" in out


def test_cli_seeded_regression_exits_nonzero(capsys):
    rc = cli_main(["--seed-regression", "double-topk"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "b0_attn/ffn" in out and "top_k" in out   # layer + primitive


def test_cli_self_test_exits_zero(capsys):
    assert cli_main(["--self-test"]) == 0


def test_cli_usage_error_exits_two(capsys):
    assert cli_main([]) == 2
