"""Kernel-body verifier tests: the interval/affine domain, the four rule
families (oob-access, grid-race, unmasked-pad, scratch-overflow), the
kernel registry sweep, and the CLI ``--kernels`` path."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.experimental import pallas as pl

from repro.analysis import lint_kernels, rule_kernel_body, self_test
from repro.analysis.__main__ import main as cli_main
from repro.analysis.intervals import AbsVal, Interval, Sym
from repro.analysis.kernel_rules import register_value_ranges
from repro.kernels import kernel_cases


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _findings(fn, *args, **kw):
    closed = jax.make_jaxpr(fn)(*args)
    return rule_kernel_body(closed, entry="test", **kw)


# ---------------------------------------------------------------------------
# The abstract domain
# ---------------------------------------------------------------------------

def test_interval_arithmetic():
    a, b = Interval(0, 7), Interval(-2, 3)
    assert (a + b) == Interval(-2, 10)
    assert (a - b) == Interval(-3, 9)
    assert (a * b) == Interval(-14, 21)
    assert a.join(b) == Interval(-2, 7)
    assert Interval(1, 9).floordiv(2) == Interval(0, 4)
    assert Interval.top().scale(0) == Interval(0, 0)


def test_absval_affine_cancellation():
    # (pid + 3) - pid must concretize to exactly [3, 3], not via ranges
    pid = Sym.fresh("pid", Interval(0, 99), "pid", axis=0)
    v = AbsVal.of_sym(pid).add(AbsVal.const(3)).sub(AbsVal.of_sym(pid))
    assert v.iv() == Interval(3, 3)
    assert v.is_const


def test_absval_scalar_mul_keeps_affine():
    it = Sym.fresh("iter", Interval(0, 9), "iter")
    v = AbsVal.of_sym(it).mul(AbsVal.const(4))
    assert v.iv() == Interval(0, 36)
    assert len(v.terms) == 1        # still affine, not widened


def test_absval_taint_union():
    a = AbsVal.interval(0, 1, reads=frozenset({1}))
    b = AbsVal.interval(2, 3, pad=frozenset({2}))
    c = a.add(b)
    assert c.reads == frozenset({1}) and c.pad == frozenset({2})


# ---------------------------------------------------------------------------
# oob-access
# ---------------------------------------------------------------------------

def _gather_call(kernel, b, k, p, g, n):
    def f(vals, pidx, packed):
        return pl.pallas_call(
            functools.partial(kernel, k_nnz=k),
            grid=(1, b),
            in_specs=[pl.BlockSpec((1, k), lambda ig, ib: (ib, 0)),
                      pl.BlockSpec((1, k), lambda ig, ib: (ib, 0)),
                      pl.BlockSpec((p, g, n), lambda ig, ib: (0, 0, 0))],
            out_specs=pl.BlockSpec((1, g * n), lambda ig, ib: (ib, 0)),
            out_shape=jax.ShapeDtypeStruct((b, g * n), jnp.float32),
        )(vals, pidx, packed)
    return f, (_sds((b, k), jnp.float32), _sds((b, k), jnp.int32),
               _sds((p, g, n), jnp.float32))


def _gather_kernel(off):
    def kern(vals_ref, pidx_ref, packed_ref, o_ref, *, k_nnz):
        vals, pidx = vals_ref[0], pidx_ref[0]
        bg, n = packed_ref.shape[1], packed_ref.shape[2]

        def body(j, acc):
            w = packed_ref[pl.ds(pidx[j] + off, 1), :, :][0]
            return acc + w * vals[j]

        acc = lax.fori_loop(0, k_nnz, body, jnp.zeros((bg, n), jnp.float32))
        o_ref[0] = acc.reshape(bg * n)
    return kern


def test_oob_provenance_gather_in_bounds_is_clean():
    kern = _gather_kernel(0)
    kern.__name__ = "_prov_ok_kernel"
    register_value_ranges(
        "_prov_ok_kernel",
        lambda refs: {1: Interval(0, refs[2].block_shape[0] - 1)})
    f, args = _gather_call(kern, 2, 8, 16, 4, 4)
    assert _findings(f, *args) == []


def test_oob_off_by_one_gather_names_kernel_and_ref():
    kern = _gather_kernel(1)
    kern.__name__ = "_prov_off1_kernel"
    register_value_ranges(
        "_prov_off1_kernel",
        lambda refs: {1: Interval(0, refs[2].block_shape[0] - 1)})
    f, args = _gather_call(kern, 2, 8, 16, 4, 4)
    fs = [x for x in _findings(f, *args) if x.rule == "oob-access"]
    assert fs, "off-by-one gather not caught"
    assert "_prov_off1_kernel" in fs[0].message
    assert "in[2]" in fs[0].message and "axis 0" in fs[0].message


def test_oob_unbounded_index_is_a_finding_not_a_pass():
    # No provenance declared: the traced gather index is unbounded, and
    # the verifier's contract is proof, not optimism.
    kern = _gather_kernel(0)
    kern.__name__ = "_prov_missing_kernel"
    f, args = _gather_call(kern, 2, 8, 16, 4, 4)
    fs = [x for x in _findings(f, *args) if x.rule == "oob-access"]
    assert fs and "in[2]" in fs[0].message


def test_oob_fori_loop_induction_bounds_are_exact():
    # x_ref row j for j in [0, 8): in bounds exactly; j+1 overflows.
    def ok(x_ref, o_ref):
        def body(j, acc):
            return acc + x_ref[pl.ds(j, 1), :][0]
        o_ref[...] = lax.fori_loop(0, 8, body, jnp.zeros((4,), jnp.float32))

    def bad(x_ref, o_ref):
        def body(j, acc):
            return acc + x_ref[pl.ds(j + 1, 1), :][0]
        o_ref[...] = lax.fori_loop(0, 8, body, jnp.zeros((4,), jnp.float32))

    def call(kernel):
        def f(x):
            return pl.pallas_call(
                kernel, grid=(1,),
                in_specs=[pl.BlockSpec((8, 4), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((4,), lambda i: (0,)),
                out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            )(x)
        return f

    assert _findings(call(ok), _sds((8, 4), jnp.float32)) == []
    fs = _findings(call(bad), _sds((8, 4), jnp.float32))
    assert any(x.rule == "oob-access" for x in fs)


# ---------------------------------------------------------------------------
# grid-race
# ---------------------------------------------------------------------------

def _accum_call(kernel, nk=2):
    def f(x, w):
        return pl.pallas_call(
            kernel, grid=(2, 1, 1, nk),
            in_specs=[
                pl.BlockSpec((1, 8, 8), lambda s, ib, ig, ik: (s, ib, ik)),
                pl.BlockSpec((1, 8, 8), lambda s, ib, ig, ik: (s, ik, ig)),
            ],
            out_specs=pl.BlockSpec((1, 8, 8),
                                   lambda s, ib, ig, ik: (s, ib, ig)),
            out_shape=jax.ShapeDtypeStruct((2, 8, 8), jnp.float32),
        )(x, w)
    return f, (_sds((2, 8, 16), jnp.float32), _sds((2, 16, 8), jnp.float32))


def test_grid_race_init_then_accumulate_is_clean():
    def kern(x_ref, w_ref, o_ref):
        @pl.when(pl.program_id(3) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[0] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    f, args = _accum_call(kern)
    assert _findings(f, *args) == []


def test_grid_race_missing_init_is_flagged():
    def kern(x_ref, w_ref, o_ref):
        o_ref[0] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    f, args = _accum_call(kern)
    fs = [x for x in _findings(f, *args) if x.rule == "grid-race"]
    assert fs and "out[2]" in fs[0].message
    assert "uninitialized" in fs[0].message


def test_grid_race_unguarded_overwrite_is_flagged():
    def kern(x_ref, w_ref, o_ref):
        # plain overwrite on a k-revisited block: last writer wins
        o_ref[0] = jnp.dot(x_ref[0], w_ref[0],
                           preferred_element_type=jnp.float32)

    f, args = _accum_call(kern)
    fs = [x for x in _findings(f, *args) if x.rule == "grid-race"]
    assert fs and "race" in fs[0].message


def test_grid_race_single_visit_needs_no_init():
    # nk == 1: the k axis has extent 1, so the output is never revisited
    def kern(x_ref, w_ref, o_ref):
        o_ref[0] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    f, args = _accum_call(kern, nk=1)
    assert [x for x in _findings(f, *args) if x.rule == "grid-race"] == []


# ---------------------------------------------------------------------------
# unmasked-pad
# ---------------------------------------------------------------------------

def _pad_call(kernel, rows=6, block=4):
    def f(x):
        return pl.pallas_call(
            kernel, grid=(-(-rows // block),),
            in_specs=[pl.BlockSpec((block, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block, 8), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, 8), jnp.float32),
        )(x)
    return f, (_sds((rows, 8), jnp.float32),)


def test_unmasked_pad_flagged_on_partial_block():
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    f, args = _pad_call(kernel=kern)
    fs = [x for x in _findings(f, *args) if x.rule == "unmasked-pad"]
    assert fs and "operand 0" in fs[0].message


def test_unmasked_pad_where_mask_launders():
    def kern(x_ref, o_ref):
        i = pl.program_id(0)
        r = lax.broadcasted_iota(jnp.int32, (4, 8), 0) + i * 4
        o_ref[...] = jnp.where(r < 6, x_ref[...] * 2.0, 0.0)

    f, args = _pad_call(kernel=kern)
    assert [x for x in _findings(f, *args) if x.rule == "unmasked-pad"] == []


def test_unmasked_pad_divisible_blocks_are_clean():
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    f, args = _pad_call(kernel=kern, rows=8, block=4)
    assert _findings(f, *args) == []


# ---------------------------------------------------------------------------
# scratch-overflow
# ---------------------------------------------------------------------------

def _scratch_call(scratch_shape):
    from jax.experimental.pallas import tpu as pltpu

    def kern(x_ref, o_ref, s_ref):
        o_ref[...] = x_ref[...]

    def f(x):
        return pl.pallas_call(
            kern, grid=(1,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
            scratch_shapes=[pltpu.VMEM(scratch_shape, jnp.float32)],
        )(x)
    return f, (_sds((8, 8), jnp.float32),)


def test_scratch_overflow_flagged_over_budget():
    f, args = _scratch_call((4096, 1024))       # 16 MiB > 8 MiB budget
    fs = [x for x in _findings(f, *args) if x.rule == "scratch-overflow"]
    assert fs and "budget" in fs[0].message


def test_scratch_within_budget_is_clean():
    f, args = _scratch_call((128, 128))         # 64 KiB
    assert _findings(f, *args) == []


# ---------------------------------------------------------------------------
# The registry sweep + self-test + CLI
# ---------------------------------------------------------------------------

def test_registry_covers_all_four_kernels():
    kinds = {c.kernel for c in kernel_cases()}
    assert kinds == {"topk_gather", "grouped_cs_matmul", "packed_matmul",
                     "kwta_hist"}


def test_lint_kernels_sweep_is_clean():
    report = lint_kernels()
    assert report.ok, report.render()
    # the sweep must actually have run over every registered case
    assert len(report.entries) == len(kernel_cases())


def test_self_test_catches_kernel_regressions():
    assert self_test() == []


def test_cli_kernels_exits_zero(capsys):
    rc = cli_main(["--kernels", "--fail-on-findings"])
    assert rc == 0
    assert "clean" in capsys.readouterr().out


def test_cli_seeded_kernel_regressions_exit_one(capsys):
    for name, needle in (("oob-gather", "oob-access"),
                         ("missing-init", "grid-race")):
        rc = cli_main(["--seed-regression", name])
        assert rc == 1
        assert needle in capsys.readouterr().out


def test_cli_no_config_no_kernels_exits_two(capsys):
    assert cli_main([]) == 2
