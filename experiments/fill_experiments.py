"""Regenerate the roofline table + hillclimb sections inside EXPERIMENTS.md
from the results JSONs."""

import json
import re
import sys

sys.path.insert(0, "src")
from repro.configs import get_config  # noqa: E402
from repro.launch.roofline import analyze, cell_roofline, to_markdown  # noqa


def terms(rec, arch):
    cfg = get_config(arch)
    rl = cell_roofline(rec, cfg)
    return rl


def fmt(rl):
    return (f"compute {rl['compute_s']*1e3:.2f} ms / memory "
            f"{rl['memory_s']*1e3:.2f} ms / collective "
            f"{rl['collective_s']*1e3:.2f} ms -> **{rl['bottleneck']}**, "
            f"MFU@bound {rl['mfu_at_bound']*100:.2f}%")


def hillclimb_section():
    base = json.load(open("experiments/dryrun_results.json"))
    hc = json.load(open("experiments/hillclimb_results.json"))

    def cell(name, arch, shape, variants, narrative, verdict=""):
        key = f"{arch}|{shape}|pod1"
        b = terms(base[key], arch)
        lines = [f"#### {name}: `{arch} x {shape}`\n",
                 f"Baseline: {fmt(b)}; mem/dev "
                 f"{base[key]['full']['memory']['peak_bytes_est']/1e9:.1f} GB\n"]
        lines.append(narrative + "\n")
        lines.append("| variant | compute (ms) | memory (ms) | collective "
                     "(ms) | bottleneck | Δ dominant | mem/dev (GB) |")
        lines.append("|---|---|---|---|---|---|---|")
        dom0 = b["bottleneck"] + "_s"
        for tag, desc in variants:
            k = f"{key}|{tag}"
            if k not in hc or not hc[k].get("ok"):
                lines.append(f"| {desc} | (failed/missing) | | | | | |")
                continue
            v = terms(hc[k], arch)
            delta = b[dom0] / max(v[dom0], 1e-12)
            lines.append(
                f"| {desc} | {v['compute_s']*1e3:.2f} | "
                f"{v['memory_s']*1e3:.2f} | {v['collective_s']*1e3:.2f} | "
                f"{v['bottleneck']} | **{delta:.2f}x** | "
                f"{hc[k]['full']['memory']['peak_bytes_est']/1e9:.1f} |")
        if verdict:
            lines.append("\n**Verdict:** " + verdict)
        return "\n".join(lines) + "\n"

    out = []
    out.append(cell(
        "Hillclimb A (worst memory-bound decode)", "musicgen_large",
        "decode_32k",
        [("int8kv", "int8 KV cache (per-row scales)"),
         ("int8kv_n8", "int8 KV + CS pack n=8 on FFN weights"),
         ("int8kv_owner", "int8 KV + shard_map row-owner cache write")],
        "Hypothesis: decode is KV-cache-byte bound (48L x 128B x 32k x 32kv "
        "x 64dh bf16 = 12.9 GB/device read+written per token); int8 "
        "quantization should halve the memory term and the cache footprint, "
        "with <2e-2 logit error (validated in tests). Packing FFN weights "
        "n=8 removes another (3 d ff)/8 bytes per layer.",
        verdict="**confirmed in direction, quantified**: footprint 24.4 -> "
        "9.0 GB (2.7x — the cell now fits the 16 GB chip) and the memory "
        "term improves 1.31x, not the naive 2x: the masked cache write "
        "re-reads/writes the full cache and non-KV traffic (weights, "
        "activations) shares the term. The extra n=8 FFN packing adds only "
        "2% — at B=128 decode this arch is cache-dominated, exactly the "
        "regime split predicted in DESIGN.md §2.1. Rung 3 (shard_map "
        "row-owner cache write, cfg.cache_write='owner') removes the "
        "masked write's redundant full-cache pass: memory term 60.5 -> "
        "43.8 ms — **1.80x total** vs the 78.9 ms baseline, with the "
        "collective term still ~0. Remaining traffic is the unavoidable "
        "attention read of the full cache + weights; next: CS-pack the "
        "attention projections."))
    out.append(cell(
        "Hillclimb B (most collective-bound, paper-relevant MoE)",
        "qwen3_moe_235b_a22b", "train_4k",
        [("cap10", "capacity factor 1.25 -> 1.0"),
         ("cap10_n8", "capacity 1.0 + expert CS pack n=4 -> n=8")],
        "Hypothesis: the MoE dispatch/combine traffic scales with the "
        "(groups, E, C, d) buffer; capacity 1.25->1.0 cuts C by 20% "
        "(dispatch collectives and buffer bytes follow); doubling the "
        "paper's pack factor halves expert-weight FLOPs+bytes (trading "
        "model quality studied in the paper's accuracy refs).",
        verdict="**largely refuted — informative**: capacity 1.25->1.0 "
        "moved compute -4.3% and memory -1.9% but the collective term not "
        "at all: qwen3's step collectives are dominated by TP residual "
        "all-reduces + ZeRO moment resharding, not MoE dispatch (the "
        "grouped dispatch of finding 0.6 already made dispatch local). "
        "Doubling the CS pack factor cuts another 9% of compute (expert "
        "matmuls halve, attention doesn't) and 24 GB/device of weights+"
        "states. The binding constraint stays memory traffic; the "
        "prescription is remat-policy tuning + the Pallas packed kernel "
        "(which removes decompress-boundary traffic), not dispatch work."))
    out.append(cell(
        "Hillclimb C (the paper's technique, R-ladder)", "smollm_360m",
        "train_4k",
        [("r64", "route_share G -> 64 (finer routing diversity)"),
         ("dense_path", "decompress-to-dense path (MXU regime)"),
         ("n8k16", "pack n=8 + k-WTA 6.25% winners")],
        "Hypothesis (from finding 0.1): the routed-activation working set "
        "scales as B*d_ff*G/R — R=64 should sit between R=1 (610 GB, "
        "infeasible) and R=G (baseline) on memory, with identical FLOPs; "
        "the dense path trades N x more MXU FLOPs for minimal temp; n=8 "
        "halves FFN FLOPs again (the paper's own scaling axis).",
        verdict="**R-ladder confirmed; crossover confirmed**: R=64 costs "
        "1.57x on the memory term and +10 GB/device vs fully-shared routes "
        "(610 GB at R=1, measured in 0.1 — the full ladder "
        "R=1/8/64/G: 610/169/26/16 GB). The decompress path *beats* the "
        "faithful path on every term at n=4 (memory 1.16x, compute 1.17x) "
        "— exactly the DESIGN.md §2.1 prediction that below N~32 the MXU "
        "regime wins under XLA; the faithful algorithm's N x advantage "
        "requires the fused Pallas kernels (grouped_cs_matmul/"
        "packed_matmul), which keep the routed working set in VMEM. n=8 + "
        "6.25% k-WTA cuts compute 1.27x at unchanged memory — the paper's "
        "sparsity axis works on FLOPs but this cell's roofline is bound by "
        "bytes, so the MFU@bound needle moves only via traffic."))
    return "\n".join(out)


def main():
    table = analyze()
    md = to_markdown(table)
    doc = open("EXPERIMENTS.md").read()
    doc = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\nReading of the table)",
                 "<!-- ROOFLINE_TABLE -->\n" + md + "\n",
                 doc, flags=re.S)
    doc = re.sub(r"<!-- HILLCLIMBS -->.*?(?=### Phase 2)",
                 "<!-- HILLCLIMBS -->\n" + hillclimb_section() + "\n",
                 doc, flags=re.S)
    open("EXPERIMENTS.md", "w").write(doc)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
